//! Work-stealing executor: N long-lived threads, one per simulated cluster
//! node, each with its own deque plus the ability to steal from the
//! busiest peer when idle.
//!
//! Placement is still locality-preferred: task `i` of a stage is enqueued
//! on worker `i % workers` (the partition's *owning* node, so cached
//! partitions and shuffle map outputs keep a stable home the fault
//! injector can target), but any idle worker may steal queued tasks from
//! the back of another worker's deque — the delay/speculative scheduling
//! story of Spark, which is what keeps one slow node from stalling a
//! whole stage.
//!
//! Straggler mitigation: once a stage is past its speculation quantile
//! (default 75% of tasks complete), tasks that have been running much
//! longer than the average completed task are re-submitted as speculative
//! duplicates on another node; the first completion wins and the
//! duplicate's result is discarded.  Task closures therefore run with
//! *at-least-once* semantics and must be idempotent — every engine task
//! is (they recompute deterministic partitions and write keyed slots).
//!
//! Fault kills: [`Executor::kill_worker`] (usually driven by a
//! [`FaultPlan`] kill rule) marks a node dead and drains its deque back
//! into the steal pool, so queued tasks migrate instead of being lost.
//!
//! Wall-clock on a 1-core CI box timeshares, so the metrics also record
//! per-worker *busy time*; Fig-6 reports both plus the busy-time skew
//! (max/mean busy nanos), the load-balance signal the stealer improves.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, RecvTimeoutError};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::fault::FaultPlan;

/// A unit of queued work; receives the id of the worker that executes it.
type Job = Box<dyn FnOnce(usize) + Send>;

/// Scheduler tuning knobs (see [`super::context::ClusterConfig`]).
#[derive(Debug, Clone)]
pub struct ExecutorOptions {
    /// Idle workers steal from the busiest peer's deque.
    pub work_stealing: bool,
    /// Re-execute stragglers speculatively near the end of a stage.
    pub speculation: bool,
    /// Fraction of a stage that must be complete before speculating.
    pub speculation_quantile: f64,
    /// Stages smaller than this never speculate.
    pub speculation_min_tasks: usize,
}

impl Default for ExecutorOptions {
    fn default() -> Self {
        Self {
            work_stealing: true,
            speculation: true,
            speculation_quantile: 0.75,
            speculation_min_tasks: 4,
        }
    }
}

/// Per-worker counters (busy nanos, tasks run, failures injected, tasks
/// stolen from peers, speculative duplicates enqueued on this worker).
#[derive(Debug, Default)]
pub struct WorkerMetrics {
    pub busy_nanos: AtomicU64,
    pub tasks: AtomicUsize,
    pub failures: AtomicUsize,
    pub steals: AtomicUsize,
    pub speculations: AtomicUsize,
}

struct SchedState {
    queues: Vec<VecDeque<Job>>,
    alive: Vec<bool>,
    shutdown: bool,
}

impl SchedState {
    /// Least-loaded alive worker — the single placement fallback shared
    /// by dead-owner reroutes and kill-drain redistribution.
    fn least_loaded_alive(&self) -> Option<usize> {
        (0..self.queues.len())
            .filter(|&v| self.alive[v])
            .min_by_key(|&v| self.queues[v].len())
    }
}

struct Shared {
    state: Mutex<SchedState>,
    cv: Condvar,
    metrics: Vec<Arc<WorkerMetrics>>,
    steal: bool,
}

struct TaskDone {
    task: usize,
    speculative: bool,
    result: Result<()>,
}

pub struct Executor {
    shared: Arc<Shared>,
    handles: Vec<Option<std::thread::JoinHandle<()>>>,
    fault: FaultPlan,
    opts: ExecutorOptions,
    task_counter: AtomicUsize,
}

fn worker_loop(w: usize, shared: Arc<Shared>) {
    loop {
        let (job, stolen) = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown || !st.alive[w] {
                    return;
                }
                if let Some(job) = st.queues[w].pop_front() {
                    break (job, false);
                }
                if shared.steal {
                    // Steal from the back of the busiest non-empty deque.
                    let victim = (0..st.queues.len())
                        .filter(|&v| v != w && !st.queues[v].is_empty())
                        .max_by_key(|&v| st.queues[v].len());
                    if let Some(v) = victim {
                        let job = st.queues[v].pop_back().expect("victim checked non-empty");
                        break (job, true);
                    }
                }
                st = shared.cv.wait(st).unwrap();
            }
        };
        if stolen {
            shared.metrics[w].steals.fetch_add(1, Ordering::Relaxed);
        }
        job(w);
    }
}

impl Executor {
    pub fn new(num_workers: usize, fault: FaultPlan) -> Self {
        Self::with_options(num_workers, fault, ExecutorOptions::default())
    }

    pub fn with_options(num_workers: usize, fault: FaultPlan, opts: ExecutorOptions) -> Self {
        assert!(num_workers > 0);
        let shared = Arc::new(Shared {
            state: Mutex::new(SchedState {
                queues: (0..num_workers).map(|_| VecDeque::new()).collect(),
                alive: vec![true; num_workers],
                shutdown: false,
            }),
            cv: Condvar::new(),
            metrics: (0..num_workers).map(|_| Arc::new(WorkerMetrics::default())).collect(),
            steal: opts.work_stealing,
        });
        let mut handles = Vec::with_capacity(num_workers);
        for w in 0..num_workers {
            let shared = shared.clone();
            let handle = std::thread::Builder::new()
                .name(format!("worker-{w}"))
                .spawn(move || worker_loop(w, shared))
                .expect("spawning worker thread");
            handles.push(Some(handle));
        }
        Self { shared, handles, fault, opts, task_counter: AtomicUsize::new(0) }
    }

    pub fn num_workers(&self) -> usize {
        self.shared.metrics.len()
    }

    pub fn metrics(&self) -> &[Arc<WorkerMetrics>] {
        &self.shared.metrics
    }

    pub fn options(&self) -> &ExecutorOptions {
        &self.opts
    }

    pub fn total_busy(&self) -> Duration {
        Duration::from_nanos(
            self.shared
                .metrics
                .iter()
                .map(|m| m.busy_nanos.load(Ordering::Relaxed))
                .sum(),
        )
    }

    /// Busy-time skew: max over workers of busy nanos divided by the mean
    /// (1.0 = perfectly balanced; large = one node did all the work).
    pub fn busy_skew(&self) -> f64 {
        let busy: Vec<u64> =
            self.shared.metrics.iter().map(|m| m.busy_nanos.load(Ordering::Relaxed)).collect();
        let total: u64 = busy.iter().sum();
        if total == 0 {
            return 1.0;
        }
        let mean = total as f64 / busy.len() as f64;
        *busy.iter().max().expect("at least one worker") as f64 / mean
    }

    pub fn fault_plan(&self) -> &FaultPlan {
        &self.fault
    }

    /// Which worker owns partition `part` (stable placement for caches,
    /// shuffle map outputs and the fault injector; execution may migrate).
    pub fn worker_for(&self, part: usize) -> usize {
        part % self.num_workers()
    }

    /// Number of workers still alive (not killed by a fault plan).
    pub fn alive_workers(&self) -> usize {
        self.shared.state.lock().unwrap().alive.iter().filter(|&&a| a).count()
    }

    /// Kill a worker: mark it dead and drain its deque back into the
    /// steal pool (queued tasks are redistributed to the least-loaded
    /// alive workers).  The last alive worker can never be killed, so a
    /// stage always retains capacity to finish.  Returns whether the kill
    /// happened.
    pub fn kill_worker(&self, w: usize) -> bool {
        {
            let mut st = self.shared.state.lock().unwrap();
            if w >= st.alive.len() || !st.alive[w] {
                return false;
            }
            if st.alive.iter().filter(|&&a| a).count() <= 1 {
                return false;
            }
            st.alive[w] = false;
            let drained: Vec<Job> = st.queues[w].drain(..).collect();
            for job in drained {
                let target =
                    st.least_loaded_alive().expect("at least one alive worker remains");
                st.queues[target].push_back(job);
            }
        }
        self.shared.cv.notify_all();
        true
    }

    /// Enqueue a job with locality preference `owner`; falls back to the
    /// least-loaded alive worker when the owner is dead.  Returns the
    /// worker the job actually landed on.
    fn enqueue(&self, owner: usize, job: Job) -> Result<usize> {
        let target = {
            let mut st = self.shared.state.lock().unwrap();
            let target = if st.alive[owner] {
                owner
            } else {
                st.least_loaded_alive().ok_or_else(|| anyhow!("all workers are dead"))?
            };
            st.queues[target].push_back(job);
            target
        };
        self.shared.cv.notify_all();
        Ok(target)
    }

    /// Run one task set: task `i` executes `f(i)`, preferring its owning
    /// worker; blocks until every task has completed at least once.
    /// Individual task errors (including injected faults) are retried up
    /// to `max_retries` times by re-invoking `f(i)` — lineage recompute
    /// happens naturally because `f` recomputes its inputs.  Near the end
    /// of the stage, stragglers may be duplicated speculatively; `f` must
    /// therefore be idempotent (every engine task is).
    pub fn run_tasks<F>(&self, num_tasks: usize, max_retries: usize, f: F) -> Result<()>
    where
        F: Fn(usize) -> Result<()> + Send + Sync + 'static,
    {
        if num_tasks == 0 {
            return Ok(());
        }
        let f = Arc::new(f);
        let (done_tx, done_rx) = channel::<TaskDone>();
        let completed: Arc<Vec<AtomicBool>> =
            Arc::new((0..num_tasks).map(|_| AtomicBool::new(false)).collect());

        let submit = |task: usize, attempt: usize, speculative: bool| -> Result<()> {
            let owner = self.worker_for(task + attempt); // retries migrate nodes
            let ordinal = self.task_counter.fetch_add(1, Ordering::Relaxed);
            if let Some(kw) = self.fault.should_kill(ordinal) {
                self.kill_worker(kw);
            }
            // Fault decisions key off the *owning* node, not the executing
            // one, so worker-keyed plans are unaffected by stealing.
            // Ordinal-keyed plans (fail_nth_task, random) replay exactly
            // only while the submission order does: retries and
            // speculative duplicates also consume ordinals, so under
            // races those plans may hit different submissions run-to-run
            // (results stay correct either way — only which attempts
            // fail varies).
            let fail_this = self.fault.should_fail(owner, ordinal, attempt);
            let f = f.clone();
            let done = done_tx.clone();
            let completed = completed.clone();
            let shared = self.shared.clone();
            let job: Job = Box::new(move |exec_w: usize| {
                if completed[task].load(Ordering::Acquire) {
                    return; // first completion already won; drop the duplicate
                }
                let m = &shared.metrics[exec_w];
                let start = Instant::now();
                let result = if fail_this {
                    m.failures.fetch_add(1, Ordering::Relaxed);
                    Err(anyhow!("injected fault on worker {owner} (task {task})"))
                } else {
                    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(task)))
                        .unwrap_or_else(|p| {
                            Err(anyhow!("task {task} panicked: {}", panic_msg(p.as_ref())))
                        })
                };
                m.busy_nanos
                    .fetch_add(start.elapsed().as_nanos() as u64, Ordering::Relaxed);
                m.tasks.fetch_add(1, Ordering::Relaxed);
                let _ = done.send(TaskDone { task, speculative, result });
            });
            let target = self.enqueue(owner, job)?;
            if speculative {
                // Counted against the worker the duplicate actually
                // landed on (the preferred owner may be dead).
                self.shared.metrics[target].speculations.fetch_add(1, Ordering::Relaxed);
            }
            Ok(())
        };

        let mut attempts = vec![0usize; num_tasks];
        let mut speculated = vec![false; num_tasks];
        let mut submit_time = Vec::with_capacity(num_tasks);
        for t in 0..num_tasks {
            submit_time.push(Instant::now());
            submit(t, 0, false)?;
        }

        let spec_enabled = self.opts.speculation && num_tasks >= self.opts.speculation_min_tasks;
        let spec_threshold = ((num_tasks as f64) * self.opts.speculation_quantile).ceil() as usize;
        let spec_threshold = spec_threshold.clamp(1, num_tasks);
        let mut done_count = 0usize;
        let mut sum_done_nanos = 0u64;
        // Straggler candidates, built lazily when the stage first crosses
        // the speculation quantile (so the scan is bounded by the tail of
        // the stage, not by num_tasks).
        let mut spec_candidates: Option<Vec<usize>> = None;

        while done_count < num_tasks {
            // The speculation quantile can only be crossed by a done
            // message, so until then (and always when speculation is off)
            // block on the channel instead of polling.
            let msg = if spec_enabled && done_count >= spec_threshold {
                match done_rx.recv_timeout(Duration::from_millis(25)) {
                    Ok(msg) => Some(msg),
                    Err(RecvTimeoutError::Timeout) => None,
                    Err(RecvTimeoutError::Disconnected) => {
                        return Err(anyhow!("all workers died mid-job"));
                    }
                }
            } else {
                Some(done_rx.recv().map_err(|_| anyhow!("all workers died mid-job"))?)
            };

            if let Some(TaskDone { task, speculative, result }) = msg {
                if !completed[task].load(Ordering::Acquire) {
                    match result {
                        Ok(()) => {
                            completed[task].store(true, Ordering::Release);
                            done_count += 1;
                            sum_done_nanos += submit_time[task].elapsed().as_nanos() as u64;
                        }
                        Err(e) => {
                            if speculative {
                                // A failed duplicate never burns the
                                // original's retry budget.
                            } else {
                                attempts[task] += 1;
                                if attempts[task] > max_retries {
                                    return Err(e.context(format!(
                                        "task {task} failed after {} attempts",
                                        attempts[task]
                                    )));
                                }
                                submit_time[task] = Instant::now();
                                submit(task, attempts[task], false)?;
                            }
                        }
                    }
                }
            }

            // Speculative re-execution: past the quantile, duplicate tasks
            // that have been in flight much longer than the average
            // completed task (first completion wins).
            if spec_enabled && done_count >= spec_threshold && done_count < num_tasks {
                let candidates = spec_candidates.get_or_insert_with(|| {
                    (0..num_tasks)
                        .filter(|&t| !completed[t].load(Ordering::Acquire))
                        .collect()
                });
                let avg = sum_done_nanos / done_count.max(1) as u64;
                let deadline = Duration::from_nanos((4 * avg).max(100_000_000));
                let mut still_waiting = Vec::with_capacity(candidates.len());
                for &t in candidates.iter() {
                    if completed[t].load(Ordering::Acquire) || speculated[t] {
                        continue; // finished or already duplicated: drop
                    }
                    if submit_time[t].elapsed() >= deadline {
                        speculated[t] = true;
                        submit(t, attempts[t] + 1, true)?;
                    } else {
                        still_waiting.push(t);
                    }
                }
                *candidates = still_waiting;
            }
        }
        Ok(())
    }
}

fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    p.downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| p.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "<non-string panic>".into())
}

impl Drop for Executor {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.cv.notify_all();
        let me = std::thread::current().id();
        for h in &mut self.handles {
            if let Some(h) = h.take() {
                // A task closure can hold the last Cluster handle, making
                // a *worker* run this drop — never join yourself, detach.
                if h.thread().id() != me {
                    let _ = h.join();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    fn no_spec() -> ExecutorOptions {
        ExecutorOptions { speculation: false, ..ExecutorOptions::default() }
    }

    #[test]
    fn runs_all_tasks_once() {
        // Speculation off: exactly-once execution of the happy path.
        let ex = Executor::with_options(4, FaultPlan::none(), no_spec());
        let count = Arc::new(AtomicUsize::new(0));
        let c = count.clone();
        ex.run_tasks(37, 0, move |_| {
            c.fetch_add(1, Ordering::SeqCst);
            Ok(())
        })
        .unwrap();
        assert_eq!(count.load(Ordering::SeqCst), 37);
    }

    #[test]
    fn no_steal_mode_preserves_modulo_placement() {
        let opts = ExecutorOptions { work_stealing: false, speculation: false, ..Default::default() };
        let ex = Executor::with_options(3, FaultPlan::none(), opts);
        ex.run_tasks(30, 0, |_| Ok(())).unwrap();
        for m in ex.metrics() {
            assert_eq!(m.tasks.load(Ordering::SeqCst), 10, "static placement is exact");
            assert_eq!(m.steals.load(Ordering::SeqCst), 0);
        }
    }

    #[test]
    fn idle_worker_steals_from_busy_queue() {
        // Worker 0's first task blocks until every other task has run.
        // Tasks 2,4,6,8 are queued behind it on worker 0's deque, so the
        // stage can only finish if worker 1 steals them.
        let ex = Executor::with_options(2, FaultPlan::none(), ExecutorOptions::default());
        let sync = Arc::new((Mutex::new(0usize), Condvar::new()));
        let s = sync.clone();
        ex.run_tasks(10, 0, move |task| {
            let (count, cv) = &*s;
            if task == 0 {
                let done = count.lock().unwrap();
                let (done, timeout) = cv
                    .wait_timeout_while(done, Duration::from_secs(20), |c| *c < 9)
                    .unwrap();
                anyhow::ensure!(
                    !timeout.timed_out(),
                    "only {} of 9 peer tasks ran: stealing is broken",
                    *done
                );
            } else {
                *count.lock().unwrap() += 1;
                cv.notify_all();
            }
            Ok(())
        })
        .unwrap();
        let stolen: usize =
            ex.metrics().iter().map(|m| m.steals.load(Ordering::SeqCst)).sum();
        assert!(stolen >= 4, "tasks 2,4,6,8 must have been stolen (got {stolen})");
    }

    #[test]
    fn straggler_is_speculatively_reexecuted() {
        // Task 0's first execution blocks until a speculative duplicate
        // has run; the stage can only finish because the duplicate's
        // completion wins.  Without speculation this test would error out
        // after the 20s guard instead of hanging.
        let ex = Executor::with_options(2, FaultPlan::none(), ExecutorOptions::default());
        let sync = Arc::new((Mutex::new(false), Condvar::new()));
        let execs = Arc::new(AtomicUsize::new(0));
        let s = sync.clone();
        let e = execs.clone();
        ex.run_tasks(8, 0, move |task| {
            if task != 0 {
                return Ok(());
            }
            let (dup_ran, cv) = &*s;
            if e.fetch_add(1, Ordering::SeqCst) == 0 {
                // Original attempt: straggle until the duplicate runs.
                let flag = dup_ran.lock().unwrap();
                let (_, timeout) = cv
                    .wait_timeout_while(flag, Duration::from_secs(20), |ran| !*ran)
                    .unwrap();
                anyhow::ensure!(!timeout.timed_out(), "no speculative duplicate was launched");
            } else {
                // Speculative duplicate: finish fast and release the original.
                *dup_ran.lock().unwrap() = true;
                cv.notify_all();
            }
            Ok(())
        })
        .unwrap();
        assert!(execs.load(Ordering::SeqCst) >= 2, "task 0 must have been duplicated");
        let specs: usize =
            ex.metrics().iter().map(|m| m.speculations.load(Ordering::SeqCst)).sum();
        assert!(specs >= 1, "speculation counter must have fired");
    }

    #[test]
    fn kill_drains_deque_back_into_steal_pool() {
        // Three workers all blocked in their first task; worker 0 is then
        // killed while its deque still holds queued tasks, which must be
        // redistributed and completed by the survivors.
        let ex = Arc::new(Executor::with_options(3, FaultPlan::none(), no_spec()));
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let count = Arc::new(AtomicUsize::new(0));

        let opener = {
            let ex = ex.clone();
            let gate = gate.clone();
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(150));
                assert!(ex.kill_worker(0), "kill must succeed");
                let (open, cv) = &*gate;
                *open.lock().unwrap() = true;
                cv.notify_all();
            })
        };

        let g = gate.clone();
        let c = count.clone();
        ex.run_tasks(12, 0, move |task| {
            if task < 3 {
                // One gate task per worker keeps all deques populated
                // until the kill has happened.
                let (open, cv) = &*g;
                let opened = open.lock().unwrap();
                let (_, timeout) = cv
                    .wait_timeout_while(opened, Duration::from_secs(20), |o| !*o)
                    .unwrap();
                anyhow::ensure!(!timeout.timed_out(), "gate never opened");
            }
            c.fetch_add(1, Ordering::SeqCst);
            Ok(())
        })
        .unwrap();
        opener.join().unwrap();

        assert_eq!(count.load(Ordering::SeqCst), 12, "drained tasks must not be lost");
        assert_eq!(ex.alive_workers(), 2);
        // New work keeps flowing around the dead node.
        let c2 = Arc::new(AtomicUsize::new(0));
        let c2c = c2.clone();
        ex.run_tasks(9, 0, move |_| {
            c2c.fetch_add(1, Ordering::SeqCst);
            Ok(())
        })
        .unwrap();
        assert_eq!(c2.load(Ordering::SeqCst), 9);
    }

    #[test]
    fn last_alive_worker_cannot_be_killed() {
        let ex = Executor::new(2, FaultPlan::none());
        assert!(ex.kill_worker(1));
        assert!(!ex.kill_worker(0), "the last worker must survive");
        assert_eq!(ex.alive_workers(), 1);
        ex.run_tasks(4, 0, |_| Ok(())).unwrap();
    }

    #[test]
    fn task_errors_are_retried() {
        let ex = Executor::with_options(2, FaultPlan::none(), no_spec());
        let tries = Arc::new(AtomicUsize::new(0));
        let t = tries.clone();
        ex.run_tasks(1, 3, move |_| {
            if t.fetch_add(1, Ordering::SeqCst) < 2 {
                anyhow::bail!("transient");
            }
            Ok(())
        })
        .unwrap();
        assert_eq!(tries.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn exhausted_retries_propagate_error() {
        let ex = Executor::new(2, FaultPlan::none());
        let err = ex
            .run_tasks(4, 1, |t| {
                if t == 2 {
                    anyhow::bail!("always fails")
                }
                Ok(())
            })
            .unwrap_err();
        assert!(format!("{err:#}").contains("task 2"));
    }

    #[test]
    fn panics_become_errors_not_hangs() {
        let ex = Executor::new(2, FaultPlan::none());
        let err = ex.run_tasks(1, 0, |_| panic!("boom")).unwrap_err();
        assert!(format!("{err:#}").contains("boom"));
    }

    #[test]
    fn injected_faults_recover_via_retry() {
        // Fail every task's first attempt whose owner is worker 0.
        let ex = Executor::with_options(
            2,
            FaultPlan::fail_first_attempt_on_worker(0),
            no_spec(),
        );
        let count = Arc::new(AtomicUsize::new(0));
        let c = count.clone();
        ex.run_tasks(8, 2, move |_| {
            c.fetch_add(1, Ordering::SeqCst);
            Ok(())
        })
        .unwrap();
        assert_eq!(count.load(Ordering::SeqCst), 8);
        let injected: usize = ex
            .metrics()
            .iter()
            .map(|m| m.failures.load(Ordering::SeqCst))
            .sum();
        assert!(injected > 0, "fault plan should have fired");
    }

    #[test]
    fn fault_plan_kill_drains_and_stage_completes() {
        // A kill rule in the fault plan fires mid-submission; the stage
        // must still complete on the surviving worker.
        let plan = FaultPlan::kill_worker_at(0, 5);
        let ex = Executor::with_options(2, plan, no_spec());
        let count = Arc::new(AtomicUsize::new(0));
        let c = count.clone();
        ex.run_tasks(16, 0, move |_| {
            c.fetch_add(1, Ordering::SeqCst);
            Ok(())
        })
        .unwrap();
        assert_eq!(count.load(Ordering::SeqCst), 16);
        assert_eq!(ex.alive_workers(), 1);
    }

    #[test]
    fn busy_skew_is_unity_when_idle() {
        let ex = Executor::new(3, FaultPlan::none());
        assert_eq!(ex.busy_skew(), 1.0);
    }
}

//! Shuffle backends — the architectural difference the paper measures.
//!
//! * [`Backend::InMemory`] (Spark): map-side buckets stay resident as
//!   native `Vec<T>`s until the consuming stage finishes.  No
//!   serialization, no disk; memory is charged to the map-side worker for
//!   the store's lifetime.
//! * [`Backend::DiskKv`] (Hadoop): every bucket is length-prefix encoded
//!   and spilled to a per-shuffle directory; reducers read the files back
//!   and decode.  Memory stays flat but each record pays the
//!   encode+write+read+decode "key-value pair conversion" tax the paper
//!   blames for HAlign v1's slowdown and HPTree's memory spikes.

use std::collections::HashMap;
use std::io::Read;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context as _, Result};

use super::context::Cluster;
use crate::obs::Counter;
use crate::util::{Decode, Encode};

/// Write a spill file atomically (unique tmp name + rename), so a reader
/// can never observe a half-written bucket even if a speculative
/// duplicate task re-writes it concurrently.
pub(crate) fn write_atomic(path: &Path, bytes: &[u8]) -> std::io::Result<()> {
    static TMP_SEQ: AtomicUsize = AtomicUsize::new(0);
    let tmp = path.with_extension(format!("tmp{}", TMP_SEQ.fetch_add(1, Ordering::Relaxed)));
    std::fs::write(&tmp, bytes)?;
    std::fs::rename(&tmp, path)
}

/// Per-slot once-only IO crediting.  Tasks run at-least-once
/// (speculation, retries, lineage recovery), so a duplicate execution
/// must *replace* its slot's credit in the shared counters, never
/// accumulate — the bytes/files numbers then record the job's footprint,
/// not how many times a task happened to re-run.  Shared by the shuffle
/// spill path and checkpoint writes.
pub(crate) struct CreditOnce<K> {
    slots: Mutex<HashMap<K, (u64, usize)>>,
}

impl<K: std::hash::Hash + Eq> CreditOnce<K> {
    pub(crate) fn new() -> Self {
        Self { slots: Mutex::new(HashMap::new()) }
    }

    /// Credit `bytes`/`files` for `key`'s slot, releasing any credit an
    /// earlier execution of the same slot already took.  The counter
    /// updates happen under the slot lock so two racing credits for the
    /// same slot can never interleave sub-before-add and transiently
    /// wrap the shared counter under a concurrent stats reader.
    pub(crate) fn credit(
        &self,
        key: K,
        bytes: u64,
        files: usize,
        bytes_counter: &Counter,
        files_counter: &Counter,
    ) {
        let mut slots = self.slots.lock().unwrap();
        let prev = slots.insert(key, (bytes, files));
        if let Some((prev_bytes, prev_files)) = prev {
            bytes_counter.fetch_sub(prev_bytes, Ordering::Relaxed);
            files_counter.fetch_sub(prev_files as u64, Ordering::Relaxed);
        }
        bytes_counter.fetch_add(bytes, Ordering::Relaxed);
        files_counter.fetch_add(files as u64, Ordering::Relaxed);
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    InMemory,
    DiskKv,
}

impl std::fmt::Display for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Backend::InMemory => write!(f, "spark/in-memory"),
            Backend::DiskKv => write!(f, "hadoop/disk-kv"),
        }
    }
}

/// Map-output store for one shuffle: buckets indexed by (map, reduce)
/// partition. Thread-safe; map tasks `put` concurrently, reduce tasks
/// `read_reduce` after the map stage completes.
///
/// Ownership is keyed to the *owning* worker (`worker_for(map_part)`),
/// not the executing worker: under the work-stealing executor a map task
/// may run anywhere, but its outputs still have a stable home node, which
/// is what lets the fault injector "lose" a node's outputs coherently.
pub struct ShuffleStore<T> {
    backend: Backend,
    cluster: Cluster,
    num_reduce: usize,
    /// In-memory buckets; also used by DiskKv for nothing (kept empty).
    mem: Mutex<HashMap<(usize, usize), Arc<Vec<T>>>>,
    /// Bytes charged per map worker (released on drop).
    charged: Mutex<Vec<(usize, usize)>>,
    /// DiskKv: once-only (bytes, spill files) crediting per (map, reduce)
    /// slot, mirroring the in-memory path's replace-and-release so the
    /// Fig-5/Table-2 IO numbers are stable run to run.
    counted: CreditOnce<(usize, usize)>,
    dir: Option<PathBuf>,
}

impl<T: Clone + Encode + Decode + crate::engine::memory::MemSize> ShuffleStore<T> {
    pub fn new(cluster: &Cluster, num_reduce: usize) -> Result<Self> {
        let backend = cluster.backend();
        let dir = match backend {
            Backend::InMemory => None,
            Backend::DiskKv => {
                let d = cluster
                    .scratch_dir()?
                    .join(format!("shuffle-{}", cluster.next_shuffle_id()));
                std::fs::create_dir_all(&d)?;
                Some(d)
            }
        };
        cluster.io().shuffles_executed.fetch_add(1, Ordering::Relaxed);
        Ok(Self {
            backend,
            cluster: cluster.clone(),
            num_reduce,
            mem: Mutex::new(HashMap::new()),
            charged: Mutex::new(Vec::new()),
            counted: CreditOnce::new(),
            dir,
        })
    }

    pub fn num_reduce(&self) -> usize {
        self.num_reduce
    }

    fn bucket_path(&self, map_part: usize, reduce_part: usize) -> PathBuf {
        // lint: allow(panic) `dir` is always Some in DiskKv mode (set in `new`),
        // and bucket_path is only reachable from DiskKv match arms
        self.dir
            .as_ref()
            .expect("disk path only in DiskKv mode")
            .join(format!("m{map_part}-r{reduce_part}.kv"))
    }

    /// Store one map task's bucket for a reduce partition.
    pub fn put(&self, map_part: usize, reduce_part: usize, data: Vec<T>) -> Result<()> {
        debug_assert!(reduce_part < self.num_reduce);
        let worker = self.cluster.executor().worker_for(map_part);
        match self.backend {
            Backend::InMemory => {
                let bytes = crate::engine::memory::slice_bytes(&data);
                self.cluster.memory().worker(worker).acquire(bytes);
                self.charged.lock().unwrap().push((worker, bytes));
                let replaced = self
                    .mem
                    .lock()
                    .unwrap()
                    .insert((map_part, reduce_part), Arc::new(data));
                if let Some(old) = replaced {
                    // A duplicate task (speculative re-execution) re-wrote
                    // this bucket: release the stale copy's charge now so
                    // the bucket stays single-counted in the Fig-5 metric.
                    let old_bytes = crate::engine::memory::slice_bytes(old.as_ref());
                    self.cluster.memory().worker(worker).release(old_bytes);
                    let mut charged = self.charged.lock().unwrap();
                    if let Some(pos) =
                        charged.iter().position(|&(w, b)| w == worker && b == old_bytes)
                    {
                        charged.remove(pos);
                    }
                }
            }
            Backend::DiskKv => {
                // Hadoop path: MapReduce's sort-merge shuffle — every
                // record is serialized, records are sorted (the framework
                // always sorts map outputs), the sort buffer pays the JVM
                // Writable-object bloat, and the spill is replicated like
                // an HDFS block (dfs.replication).
                let cfg = self.cluster.config();
                let mut records: Vec<Vec<u8>> =
                    data.iter().map(|item| item.to_bytes()).collect();
                let payload: usize = records.iter().map(Vec::len).sum();
                let mem = self.cluster.memory().worker(worker);
                // Sort buffer + merge scratch, bloated by the KV factor.
                let charge = payload * 2 * cfg.kv_overhead.max(1);
                mem.acquire(charge);
                records.sort_unstable();
                let mut buf = Vec::with_capacity(payload + 8 * records.len() + 8);
                (records.len() as u64).encode(&mut buf);
                for r in &records {
                    (r.len() as u64).encode(&mut buf);
                    buf.extend_from_slice(r);
                }
                let result = (|| -> Result<(u64, usize)> {
                    let mut written = 0u64;
                    let mut files = 0usize;
                    for copy in 0..cfg.disk_replication.max(1) {
                        let path = self.bucket_path(map_part, reduce_part);
                        let path = if copy == 0 {
                            path
                        } else {
                            path.with_extension(format!("kv.r{copy}"))
                        };
                        write_atomic(&path, &buf)
                            .with_context(|| format!("spilling {}", path.display()))?;
                        written += buf.len() as u64;
                        files += 1;
                    }
                    Ok((written, files))
                })();
                mem.release(charge);
                let (written, files) = result?;
                let io = self.cluster.io();
                self.counted.credit(
                    (map_part, reduce_part),
                    written,
                    files,
                    &io.shuffle_bytes_written,
                    &io.spill_files,
                );
            }
        }
        Ok(())
    }

    /// Gather every map task's bucket for `reduce_part` (map stage must be
    /// complete). `num_map` tells the reader how many files to expect.
    pub fn read_reduce(&self, reduce_part: usize, num_map: usize) -> Result<Vec<T>> {
        let mut out = Vec::new();
        match self.backend {
            Backend::InMemory => {
                let mem = self.mem.lock().unwrap();
                for m in 0..num_map {
                    if let Some(bucket) = mem.get(&(m, reduce_part)) {
                        out.extend(bucket.iter().cloned());
                    }
                }
            }
            Backend::DiskKv => {
                let worker = self.cluster.executor().worker_for(reduce_part);
                for m in 0..num_map {
                    let path = self.bucket_path(m, reduce_part);
                    if !path.exists() {
                        continue; // empty bucket was never written
                    }
                    let mut buf = Vec::new();
                    std::fs::File::open(&path)
                        .and_then(|mut f| f.read_to_end(&mut buf))
                        .with_context(|| format!("reading {}", path.display()))?;
                    self.cluster
                        .io()
                        .shuffle_bytes_read
                        .fetch_add(buf.len() as u64, Ordering::Relaxed);
                    // Reduce-side merge buffer, with the JVM KV bloat.
                    let mem = self.cluster.memory().worker(worker);
                    let charge = buf.len() * self.cluster.config().kv_overhead.max(1);
                    mem.acquire(charge);
                    let decoded = decode_framed::<T>(&buf);
                    mem.release(charge);
                    out.extend(decoded?);
                }
            }
        }
        Ok(out)
    }

    /// Drop map outputs for partitions owned by `worker` — simulates losing
    /// that node after the map stage; the scheduler must recompute them.
    pub fn drop_worker_outputs(&self, worker: usize, num_map: usize) {
        match self.backend {
            Backend::InMemory => {
                let mut mem = self.mem.lock().unwrap();
                mem.retain(|(m, _), _| self.cluster.executor().worker_for(*m) != worker);
            }
            Backend::DiskKv => {
                for m in 0..num_map {
                    if self.cluster.executor().worker_for(m) == worker {
                        for r in 0..self.num_reduce {
                            let _ = std::fs::remove_file(self.bucket_path(m, r));
                        }
                    }
                }
            }
        }
    }

    /// Whether map partition `m` has a *complete* set of outputs — every
    /// reduce bucket present.  Map tasks write all `num_reduce` buckets
    /// (empty ones included), so a partial set means the outputs were
    /// lost or a recompute is still in flight; the recovery probe must
    /// not treat it as done, or a concurrent reduce task would read its
    /// own still-missing bucket as empty.
    pub fn map_part_present(&self, m: usize) -> bool {
        match self.backend {
            Backend::InMemory => {
                let mem = self.mem.lock().unwrap();
                (0..self.num_reduce).all(|r| mem.contains_key(&(m, r)))
            }
            Backend::DiskKv => (0..self.num_reduce).all(|r| self.bucket_path(m, r).exists()),
        }
    }

    /// Which map partitions currently have complete outputs (all reduce
    /// buckets, see [`map_part_present`]) — used by recompute-after-loss.
    ///
    /// [`map_part_present`]: ShuffleStore::map_part_present
    pub fn present_map_parts(&self, num_map: usize) -> Vec<bool> {
        (0..num_map).map(|m| self.map_part_present(m)).collect()
    }
}

impl<T> Drop for ShuffleStore<T> {
    fn drop(&mut self) {
        for (worker, bytes) in self.charged.lock().unwrap().drain(..) {
            self.cluster.memory().worker(worker).release(bytes);
        }
        if let Some(dir) = &self.dir {
            let _ = std::fs::remove_dir_all(dir);
        }
    }
}

/// Decode the sort-merge spill framing: u64 count, then per record a u64
/// length prefix + encoded bytes (records were sorted lexicographically
/// by encoding on the map side).
fn decode_framed<T: Decode>(mut bytes: &[u8]) -> Result<Vec<T>> {
    let input = &mut bytes;
    let count = u64::decode(input)? as usize;
    let mut out = Vec::with_capacity(count.min(1 << 20));
    for _ in 0..count {
        let len = u64::decode(input)? as usize;
        anyhow::ensure!(input.len() >= len, "spill record truncated");
        let (head, tail) = input.split_at(len);
        let mut head = head;
        out.push(T::decode(&mut head)?);
        anyhow::ensure!(head.is_empty(), "spill record has trailing bytes");
        *input = tail;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::context::{Cluster, ClusterConfig};

    fn mk(backend: Backend) -> Cluster {
        let mut cfg = ClusterConfig::spark(3);
        cfg.backend = backend;
        Cluster::new(cfg)
    }

    fn roundtrip(backend: Backend) {
        let c = mk(backend);
        let store: ShuffleStore<(u32, String)> = ShuffleStore::new(&c, 2).unwrap();
        store.put(0, 0, vec![(1, "a".into()), (2, "b".into())]).unwrap();
        store.put(1, 0, vec![(3, "c".into())]).unwrap();
        store.put(1, 1, vec![(4, "d".into())]).unwrap();
        let r0 = store.read_reduce(0, 2).unwrap();
        assert_eq!(r0.len(), 3);
        let r1 = store.read_reduce(1, 2).unwrap();
        assert_eq!(r1, vec![(4, "d".to_string())]);
        assert!(store.read_reduce(0, 2).unwrap().len() == 3, "re-read ok");
    }

    #[test]
    fn inmemory_roundtrip() {
        roundtrip(Backend::InMemory);
    }

    #[test]
    fn diskkv_roundtrip_and_counters() {
        let c = mk(Backend::DiskKv);
        let store: ShuffleStore<(u32, u32)> = ShuffleStore::new(&c, 2).unwrap();
        store.put(0, 0, vec![(1, 10), (2, 20)]).unwrap();
        store.put(0, 1, vec![(3, 30)]).unwrap();
        assert_eq!(store.read_reduce(0, 1).unwrap(), vec![(1, 10), (2, 20)]);
        let st = c.stats();
        assert!(st.shuffle_bytes_written > 0, "disk mode must spill");
        assert!(st.shuffle_bytes_read > 0);
    }

    #[test]
    fn inmemory_never_touches_disk() {
        let c = mk(Backend::InMemory);
        let store: ShuffleStore<(u32, u32)> = ShuffleStore::new(&c, 2).unwrap();
        store.put(0, 0, vec![(1, 10)]).unwrap();
        store.read_reduce(0, 1).unwrap();
        assert_eq!(c.stats().shuffle_bytes_written, 0);
        assert_eq!(c.stats().shuffle_bytes_read, 0);
    }

    #[test]
    fn inmemory_charges_and_releases_memory() {
        let c = mk(Backend::InMemory);
        {
            let store: ShuffleStore<(u64, u64)> = ShuffleStore::new(&c, 1).unwrap();
            store.put(0, 0, vec![(1, 1); 100]).unwrap();
            assert!(c.memory().total_current() >= 1600);
        }
        assert_eq!(c.memory().total_current(), 0, "drop releases charges");
    }

    #[test]
    fn worker_loss_drops_only_that_workers_outputs() {
        let c = mk(Backend::InMemory); // 3 workers: parts 0,3 -> w0; 1,4 -> w1...
        let store: ShuffleStore<(u32, u32)> = ShuffleStore::new(&c, 1).unwrap();
        for m in 0..4 {
            store.put(m, 0, vec![(m as u32, 0)]).unwrap();
        }
        store.drop_worker_outputs(0, 4);
        let present = store.present_map_parts(4);
        assert_eq!(present, vec![false, true, true, false]); // w0 owned 0 and 3
    }

    #[test]
    fn backends_produce_byte_identical_grouped_output() {
        // Same job, both backends, canonicalized (groups sorted by key,
        // values sorted within each group — MapReduce sorts map outputs,
        // Spark preserves arrival order, so raw order is backend-defined)
        // and then *encoded*: the byte streams must match exactly.
        let gen_pairs = || -> Vec<(u32, String)> {
            let mut rng = crate::util::Rng::seed_from_u64(0xC0FFEE);
            (0..300)
                .map(|i| (rng.below(23) as u32, format!("v{i}-{}", rng.below(1000))))
                .collect()
        };
        let canonical = |c: &Cluster| -> Vec<u8> {
            let mut groups = c.parallelize(gen_pairs(), 5).group_by_key(4).collect().unwrap();
            for (_, vs) in groups.iter_mut() {
                vs.sort();
            }
            groups.sort();
            groups.to_bytes()
        };
        let spark = canonical(&Cluster::new(ClusterConfig::spark(3)));
        let hadoop = canonical(&Cluster::new(ClusterConfig::hadoop(3)));
        assert!(!spark.is_empty());
        assert_eq!(spark, hadoop, "backends must agree byte-for-byte");
    }

    #[test]
    fn duplicate_diskkv_puts_count_bucket_bytes_once() {
        // Speculative / retried map tasks re-put the same (map, reduce)
        // slot under at-least-once execution; written bytes and spill
        // files must be credited once per slot, not once per execution.
        let c = mk(Backend::DiskKv);
        let store: ShuffleStore<(u32, u32)> = ShuffleStore::new(&c, 2).unwrap();
        store.put(0, 0, vec![(1, 10), (2, 20)]).unwrap();
        store.put(0, 1, vec![(3, 30)]).unwrap();
        let once = c.stats();
        assert!(once.shuffle_bytes_written > 0);
        // Re-run the same map task (identical deterministic output).
        store.put(0, 0, vec![(1, 10), (2, 20)]).unwrap();
        store.put(0, 1, vec![(3, 30)]).unwrap();
        let twice = c.stats();
        assert_eq!(
            twice.shuffle_bytes_written, once.shuffle_bytes_written,
            "duplicate puts must not double-count bytes"
        );
        assert_eq!(
            c.io().spill_files.load(Ordering::Relaxed),
            2 * c.config().disk_replication as u64,
            "two buckets x replication, regardless of re-puts"
        );
    }

    #[test]
    fn recovery_reput_keeps_counters_stable() {
        // Losing a worker's outputs and recomputing them (the lineage
        // recovery path re-puts the same slots) must leave the write-side
        // counters exactly where they were.
        let c = mk(Backend::DiskKv);
        let store: ShuffleStore<(u32, u32)> = ShuffleStore::new(&c, 2).unwrap();
        for m in 0..4 {
            store.put(m, 0, vec![(m as u32, 1)]).unwrap();
            store.put(m, 1, vec![(m as u32, 2)]).unwrap();
        }
        let before = c.stats().shuffle_bytes_written;
        store.drop_worker_outputs(0, 4);
        for m in [0usize, 3] {
            // worker 0 owned map parts 0 and 3 (3 workers)
            store.put(m, 0, vec![(m as u32, 1)]).unwrap();
            store.put(m, 1, vec![(m as u32, 2)]).unwrap();
        }
        assert_eq!(c.stats().shuffle_bytes_written, before, "recovery must not inflate IO");
    }

    #[test]
    fn map_part_present_requires_every_bucket() {
        for backend in [Backend::InMemory, Backend::DiskKv] {
            let c = mk(backend);
            let store: ShuffleStore<(u32, u32)> = ShuffleStore::new(&c, 2).unwrap();
            assert!(!store.map_part_present(0));
            // A half-written map output (recompute in flight) is NOT
            // present — a reduce task must not skip recovery on it.
            store.put(0, 0, vec![(1, 1)]).unwrap();
            assert!(!store.map_part_present(0), "partial outputs are not complete");
            store.put(0, 1, Vec::new()).unwrap();
            assert!(store.map_part_present(0), "empty buckets still count once written");
            store.put(1, 0, Vec::new()).unwrap();
            store.put(1, 1, Vec::new()).unwrap();
            assert!(store.map_part_present(1));
            store.drop_worker_outputs(0, 2);
            assert!(!store.map_part_present(0), "worker 0 owned map part 0");
            assert!(store.map_part_present(1), "worker 1's outputs survive");
        }
    }

    #[test]
    fn missing_buckets_read_as_empty() {
        let c = mk(Backend::DiskKv);
        let store: ShuffleStore<(u32, u32)> = ShuffleStore::new(&c, 2).unwrap();
        assert!(store.read_reduce(1, 3).unwrap().is_empty());
    }
}

//! Cluster context: configuration + shared services (executor, memory
//! tracker, shuffle I/O counters) behind a cheaply clonable handle — the
//! `SparkContext` analogue.

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use super::executor::{Executor, ExecutorOptions};
use super::fault::FaultPlan;
use super::memory::MemoryTracker;
use super::rdd::{Data, Rdd};
use super::shuffle::Backend;
use crate::obs::{Counter, Registry, TraceSink};

/// Engine configuration — the knobs the paper's experiments sweep.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Simulated cluster nodes (paper: 12 workstations).
    pub workers: usize,
    /// Default partition count for `parallelize` (Spark: 2-4x cores).
    pub default_partitions: usize,
    /// Shuffle/job-boundary backend: `InMemory` = Spark, `DiskKv` = Hadoop.
    pub backend: Backend,
    /// Task retry budget (lineage recompute on failure).
    pub max_retries: usize,
    /// Fault injection plan.
    pub fault: FaultPlan,
    /// Work-stealing / speculative-execution scheduler knobs.
    pub scheduler: ExecutorOptions,
    /// Base seed for engine-internal randomness (sampling etc.).
    pub seed: u64,
    /// DiskKv (Hadoop) only: HDFS-style block replication — every spill
    /// is written this many times (dfs.replication defaults to 3).
    pub disk_replication: usize,
    /// DiskKv only: JVM Writable-object bloat factor applied to the
    /// sort/merge buffers MapReduce materializes around each spill
    /// ("many key-value pair conversion operators ... result in high
    /// memory occupancy rate" — paper §Results).
    pub kv_overhead: usize,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            workers: 4,
            default_partitions: 8,
            backend: Backend::InMemory,
            max_retries: 2,
            fault: FaultPlan::none(),
            scheduler: ExecutorOptions::default(),
            seed: 0x4A11C2,
            disk_replication: 3,
            kv_overhead: 3,
        }
    }
}

impl ClusterConfig {
    pub fn spark(workers: usize) -> Self {
        Self {
            workers,
            default_partitions: (workers * 2).max(4),
            backend: Backend::InMemory,
            ..Self::default()
        }
    }

    /// Hadoop emulation: disk key-value shuffle + disk job boundaries.
    pub fn hadoop(workers: usize) -> Self {
        Self { backend: Backend::DiskKv, ..Self::spark(workers) }
    }
}

/// Cluster-wide I/O counters (shuffle + checkpoint + spill traffic).
/// Each field is a [`Counter`] registered in the cluster's metrics
/// registry, so the same atomics that feed `ClusterStats` are scraped
/// verbatim by `GET /metrics`; `Counter` keeps `fetch_add`/`fetch_sub`/
/// `load` shims so call sites read like the bare atomics they replaced.
#[derive(Debug)]
pub struct IoCounters {
    pub shuffle_bytes_written: Arc<Counter>,
    pub shuffle_bytes_read: Arc<Counter>,
    pub spill_files: Arc<Counter>,
    pub shuffles_executed: Arc<Counter>,
    /// Payload bytes actually decoded from checkpoint files.  With the
    /// per-element offset index a tail slice decodes only its own range,
    /// so this stays proportional to elements consumed, not file size
    /// (regression hook for the seek-instead-of-prefix-decode path).
    pub checkpoint_bytes_decoded: Arc<Counter>,
    /// Distance-matrix tiles spilled to disk by the `TileStore`.
    pub distmat_spill_files: Arc<Counter>,
    /// Spilled tiles read back from disk for row streaming / NJ merges.
    pub distmat_spill_reads: Arc<Counter>,
}

impl IoCounters {
    /// Single registration site for the I/O metric families (W8 pins
    /// that); called once per cluster with the executor's registry.
    pub fn register(registry: &Registry) -> Self {
        Self {
            shuffle_bytes_written: registry.register_counter(
                "halign_shuffle_bytes_written_total",
                "Bytes written to shuffle map outputs",
            ),
            shuffle_bytes_read: registry.register_counter(
                "halign_shuffle_bytes_read_total",
                "Bytes read from shuffle map outputs",
            ),
            spill_files: registry.register_counter(
                "halign_spill_files_total",
                "Shuffle spill files written (DiskKv backend x replication)",
            ),
            shuffles_executed: registry.register_counter(
                "halign_shuffles_executed_total",
                "Shuffle stages executed",
            ),
            checkpoint_bytes_decoded: registry.register_counter(
                "halign_checkpoint_bytes_decoded_total",
                "Payload bytes decoded from checkpoint files",
            ),
            distmat_spill_files: registry.register_counter(
                "halign_distmat_spill_files_total",
                "Distance-matrix tiles spilled to disk by the TileStore",
            ),
            distmat_spill_reads: registry.register_counter(
                "halign_distmat_spill_reads_total",
                "Spilled distance-matrix tiles read back from disk",
            ),
        }
    }
}

pub(crate) struct ClusterInner {
    pub config: ClusterConfig,
    pub executor: Executor,
    pub memory: MemoryTracker,
    pub io: IoCounters,
    pub shuffle_seq: AtomicUsize,
    pub scratch_dir: PathBuf,
}

/// Handle to a running cluster; clone freely (all clones share state).
#[derive(Clone)]
pub struct Cluster {
    pub(crate) inner: Arc<ClusterInner>,
}

impl Cluster {
    pub fn new(config: ClusterConfig) -> Self {
        let executor = Executor::with_options(
            config.workers,
            config.fault.clone(),
            config.scheduler.clone(),
        );
        let memory = MemoryTracker::new(config.workers);
        // All subsystems share the executor's registry: one scrape
        // surface per cluster.
        let io = IoCounters::register(executor.registry());
        let scratch_dir = std::env::temp_dir().join(format!(
            "halign2-{}-{}",
            std::process::id(),
            NEXT_CLUSTER_ID.fetch_add(1, Ordering::Relaxed)
        ));
        Self {
            inner: Arc::new(ClusterInner {
                config,
                executor,
                memory,
                io,
                shuffle_seq: AtomicUsize::new(0),
                scratch_dir,
            }),
        }
    }

    pub fn config(&self) -> &ClusterConfig {
        &self.inner.config
    }

    pub fn num_workers(&self) -> usize {
        self.inner.config.workers
    }

    pub fn backend(&self) -> Backend {
        self.inner.config.backend
    }

    pub fn memory(&self) -> &MemoryTracker {
        &self.inner.memory
    }

    pub fn io(&self) -> &IoCounters {
        &self.inner.io
    }

    /// The cluster-wide metrics registry (engine + I/O families; the
    /// server adds its request/cache families to the same instance).
    pub fn registry(&self) -> &Arc<Registry> {
        self.inner.executor.registry()
    }

    /// The executor's lifecycle trace sink (enabled via
    /// `ClusterConfig::scheduler.trace_capacity`).
    pub fn trace(&self) -> &Arc<TraceSink> {
        self.inner.executor.trace()
    }

    pub(crate) fn executor(&self) -> &Executor {
        &self.inner.executor
    }

    pub(crate) fn scratch_dir(&self) -> Result<PathBuf> {
        std::fs::create_dir_all(&self.inner.scratch_dir)?;
        Ok(self.inner.scratch_dir.clone())
    }

    pub(crate) fn next_shuffle_id(&self) -> usize {
        self.inner.shuffle_seq.fetch_add(1, Ordering::Relaxed)
    }

    /// Distribute a local collection across `parts` partitions
    /// (round-robin chunks, Spark's `parallelize`).
    pub fn parallelize<T: Data>(&self, items: Vec<T>, parts: usize) -> Rdd<T> {
        Rdd::from_vec(self.clone(), items, parts.max(1))
    }

    pub fn parallelize_default<T: Data>(&self, items: Vec<T>) -> Rdd<T> {
        self.parallelize(items, self.inner.config.default_partitions)
    }

    /// Dispatch `n` empty tasks through the executor (benchmarks the
    /// scheduling overhead in isolation).
    pub fn executor_probe(&self, n: usize) -> Result<()> {
        self.inner.executor.run_tasks(n, 0, |_| Ok(()))
    }

    /// Snapshot of scheduling/IO/memory stats for reports.
    pub fn stats(&self) -> ClusterStats {
        let m = &self.inner.executor;
        ClusterStats {
            workers: self.num_workers(),
            tasks_run: m
                .metrics()
                .iter()
                .map(|w| w.tasks.load(Ordering::Relaxed))
                .sum(),
            injected_failures: m
                .metrics()
                .iter()
                .map(|w| w.failures.load(Ordering::Relaxed))
                .sum(),
            tasks_stolen: m
                .metrics()
                .iter()
                .map(|w| w.steals.load(Ordering::Relaxed))
                .sum(),
            steal_batches: m
                .metrics()
                .iter()
                .map(|w| w.steal_batches.load(Ordering::Relaxed))
                .sum(),
            lock_contentions: m
                .metrics()
                .iter()
                .map(|w| w.lock_contention.load(Ordering::Relaxed))
                .sum(),
            speculative_launches: m
                .metrics()
                .iter()
                .map(|w| w.speculations.load(Ordering::Relaxed))
                .sum(),
            total_busy: m.total_busy(),
            busy_skew: m.busy_skew(),
            task_p50_ms: m.obs().task_exec.percentile_ms(0.50),
            task_p99_ms: m.obs().task_exec.percentile_ms(0.99),
            shuffle_bytes_written: self.inner.io.shuffle_bytes_written.get(),
            shuffle_bytes_read: self.inner.io.shuffle_bytes_read.get(),
            shuffles_executed: self.inner.io.shuffles_executed.get() as usize,
            avg_max_memory_bytes: self.inner.memory.avg_max_bytes(),
            max_peak_memory_bytes: self.inner.memory.max_peak_bytes(),
            stage_edges: stage_dependency_edges(m.stages_run()),
        }
    }
}

static NEXT_CLUSTER_ID: AtomicUsize = AtomicUsize::new(0);

impl Drop for ClusterInner {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.scratch_dir);
    }
}

/// Point-in-time engine statistics (consumed by metrics/ and the benches).
#[derive(Debug, Clone)]
pub struct ClusterStats {
    pub workers: usize,
    pub tasks_run: usize,
    pub injected_failures: usize,
    /// Tasks migrated out of their queued deque by work stealing.
    pub tasks_stolen: usize,
    /// Steal operations; with steal-half batching each one migrates up to
    /// half the victim's deque, so `tasks_stolen / steal_batches` is the
    /// mean batch size.
    pub steal_batches: usize,
    /// Scheduler-lock `try_lock` misses — the lock-contention proxy that
    /// separates the sharded scheduler from the global-mutex baseline.
    pub lock_contentions: usize,
    /// Speculative straggler duplicates launched.
    pub speculative_launches: usize,
    pub total_busy: Duration,
    /// Max/mean per-worker busy nanos (1.0 = perfectly balanced).
    pub busy_skew: f64,
    /// Median worker-side task execution latency in milliseconds, from
    /// the registry's log2 histogram (0.0 before any task ran).
    pub task_p50_ms: f64,
    /// 99th-percentile task execution latency in milliseconds.
    pub task_p99_ms: f64,
    pub shuffle_bytes_written: u64,
    pub shuffle_bytes_read: u64,
    pub shuffles_executed: usize,
    pub avg_max_memory_bytes: f64,
    pub max_peak_memory_bytes: usize,
    /// Stage dependency edges `(from, to)` over the stage ids packed
    /// into trace payloads.  `run_tasks` is a barrier, so the stages a
    /// job ran form a sequential chain — exactly the shuffle ordering
    /// the engine enforces — and the profiler's critical path walks it.
    pub stage_edges: Vec<(u64, u64)>,
}

/// The dependency edges implied by barrier-ordered stages `1..=stages`:
/// stage `s + 1` cannot start before stage `s` finished.
pub fn stage_dependency_edges(stages: u64) -> Vec<(u64, u64)> {
    (1..stages).map(|s| (s, s + 1)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spark_and_hadoop_presets() {
        let s = ClusterConfig::spark(12);
        assert_eq!(s.workers, 12);
        assert_eq!(s.backend, Backend::InMemory);
        let h = ClusterConfig::hadoop(12);
        assert_eq!(h.backend, Backend::DiskKv);
    }

    #[test]
    fn stats_start_clean() {
        let c = Cluster::new(ClusterConfig::spark(2));
        let st = c.stats();
        assert_eq!(st.tasks_run, 0);
        assert_eq!(st.shuffle_bytes_written, 0);
        assert_eq!(st.tasks_stolen, 0);
        assert_eq!(st.steal_batches, 0);
        assert_eq!(st.lock_contentions, 0);
        assert_eq!(st.speculative_launches, 0);
        assert_eq!(st.busy_skew, 1.0, "idle cluster is trivially balanced");
    }

    #[test]
    fn stats_export_stage_dependency_edges() {
        let c = Cluster::new(ClusterConfig::spark(2));
        assert!(c.stats().stage_edges.is_empty(), "no stages yet, no edges");
        c.executor_probe(4).unwrap();
        c.executor_probe(4).unwrap();
        c.executor_probe(4).unwrap();
        assert_eq!(c.stats().stage_edges, vec![(1, 2), (2, 3)], "barrier chain");
    }

    #[test]
    fn scheduler_options_reach_the_executor() {
        use crate::engine::SchedulerMode;
        let mut cfg = ClusterConfig::spark(2);
        cfg.scheduler.work_stealing = false;
        cfg.scheduler.speculation = false;
        cfg.scheduler.mode = SchedulerMode::GlobalLock;
        let c = Cluster::new(cfg);
        assert!(!c.executor().options().work_stealing);
        assert!(!c.executor().options().speculation);
        assert_eq!(c.executor().options().mode, SchedulerMode::GlobalLock);
        // Sharded is the default architecture.
        let d = Cluster::new(ClusterConfig::spark(2));
        assert_eq!(d.executor().options().mode, SchedulerMode::Sharded);
    }

    #[test]
    fn scratch_dir_created_and_cleaned() {
        let dir;
        {
            let c = Cluster::new(ClusterConfig::hadoop(2));
            dir = c.scratch_dir().unwrap();
            assert!(dir.exists());
        }
        assert!(!dir.exists(), "scratch dir should be removed on drop");
    }
}

//! Deterministic synthetic dataset generators — the stand-ins for the
//! paper's datasets (Table 1), per the substitution table in DESIGN.md §3:
//!
//! | Paper                         | Here                                   |
//! |-------------------------------|----------------------------------------|
//! | Φ_DNA: 672 human mito genomes | [`DatasetSpec::mito`]: ancestral 16.5 kb
//! |   (~16,569 bp, >99% similar)  |   genome + ~0.2% point mutations/indels |
//! | Φ_RNA: 16S rRNA (~1.4 kb)     | [`DatasetSpec::rrna`]: 3-10% divergence,|
//! |                               |   indel-rich, clade structure           |
//! | Φ_Protein: BAliBASE R10       | [`DatasetSpec::protein`]: BLOSUM-       |
//! |   (19-4895 aa, avg 459)       |   weighted mutations over ancestors     |
//!
//! The paper's 100x/1000x replication re-amplifies the originals —
//! [`DatasetSpec::scale`] does the same with fresh per-replica mutations,
//! so scaled datasets are not byte-copies and still exercise the full
//! alignment path.  All generation is seeded and reproducible.

use crate::fasta::{alphabet::substitution_matrix, Alphabet, Sequence};
use crate::util::Rng;

/// Which of the paper's dataset families to generate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    /// Φ_DNA — ultra-similar mitochondrial genomes.
    MitoDna,
    /// Φ_RNA — 16S-like rRNA, moderately divergent.
    Rrna,
    /// Φ_Protein — BAliBASE-like protein families.
    Protein,
}

/// Generation parameters; presets mirror Table 1 rows (optionally scaled
/// down via `length_scale` to fit CI budgets — documented in EXPERIMENTS.md).
#[derive(Debug, Clone)]
pub struct DatasetSpec {
    pub family: Family,
    /// Number of sequences.
    pub count: usize,
    /// Ancestral sequence length (before indels).
    pub base_len: usize,
    /// Per-residue substitution probability.
    pub sub_rate: f64,
    /// Per-residue insertion/deletion probability (each).
    pub indel_rate: f64,
    pub seed: u64,
}

impl DatasetSpec {
    /// Φ_DNA(1x): 672 mito genomes. `length_scale` shrinks the 16.5 kb
    /// genome for quick runs (1.0 = paper scale).
    pub fn mito(length_scale: f64, seed: u64) -> Self {
        Self {
            family: Family::MitoDna,
            count: 672,
            base_len: ((16_569.0 * length_scale) as usize).max(64),
            sub_rate: 0.002,
            indel_rate: 0.0004,
            seed,
        }
    }

    /// Φ_RNA(small)-like: 16S rRNA family (count configurable; paper:
    /// 108,453 at ~1.4 kb).
    pub fn rrna(count: usize, length_scale: f64, seed: u64) -> Self {
        Self {
            family: Family::Rrna,
            count,
            base_len: ((1_440.0 * length_scale) as usize).max(48),
            sub_rate: 0.05,
            indel_rate: 0.008,
            seed,
        }
    }

    /// Φ_Protein-like: BAliBASE R10 families (paper: 17,892 seqs, avg 459
    /// aa). Lengths are drawn per family between 19 and ~4x the average.
    pub fn protein(count: usize, length_scale: f64, seed: u64) -> Self {
        Self {
            family: Family::Protein,
            count,
            base_len: ((459.0 * length_scale) as usize).max(19),
            sub_rate: 0.12,
            indel_rate: 0.015,
            seed,
        }
    }

    /// The paper's 100x/1000x amplification: same spec, more sequences,
    /// fresh per-replica mutations (seed folded with the factor).
    pub fn scale(&self, factor: usize) -> Self {
        Self {
            count: self.count * factor,
            seed: self.seed ^ (factor as u64).wrapping_mul(0xA5A5_5A5A),
            ..self.clone()
        }
    }

    pub fn alphabet(&self) -> Alphabet {
        match self.family {
            Family::Protein => Alphabet::Protein,
            _ => Alphabet::Dna,
        }
    }

    /// Generate the full dataset.
    pub fn generate(&self) -> Vec<Sequence> {
        let mut rng = Rng::seed_from_u64(self.seed);
        match self.family {
            Family::MitoDna => mito_genomes(self, &mut rng),
            Family::Rrna => rrna_family(self, &mut rng),
            Family::Protein => protein_families(self, &mut rng),
        }
    }

    /// Generate only sequences [lo, hi).
    pub fn generate_range(&self, lo: usize, hi: usize) -> Vec<Sequence> {
        let all = self.generate();
        all[lo.min(all.len())..hi.min(all.len())].to_vec()
    }
}

fn random_residues(len: usize, alphabet: Alphabet, rng: &mut Rng) -> Vec<u8> {
    (0..len).map(|_| rng.below(alphabet.residues()) as u8).collect()
}

/// Apply substitutions + indels to an ancestor (descent with mutation).
fn mutate(
    ancestor: &[u8],
    alphabet: Alphabet,
    sub_rate: f64,
    indel_rate: f64,
    rng: &mut Rng,
    sub_weights: Option<&[f32]>, // substitution-matrix row weights (proteins)
) -> Vec<u8> {
    let mut out = Vec::with_capacity(ancestor.len() + 8);
    let residues = alphabet.residues();
    for &c in ancestor {
        if rng.chance(indel_rate) {
            continue; // deletion
        }
        if rng.chance(indel_rate) {
            out.push(rng.below(residues) as u8); // insertion before c
        }
        if rng.chance(sub_rate) {
            let next = match sub_weights {
                Some(w) => {
                    // Replacement residue ~ exp(score(c, x)/2) over the
                    // substitution row — mimics accepted point mutations.
                    let alpha = alphabet.size();
                    let row = &w[c as usize * alpha..c as usize * alpha + residues];
                    let weights: Vec<f64> =
                        row.iter().map(|&s| (s as f64 / 2.0).exp()).collect();
                    rng.weighted(&weights) as u8
                }
                None => {
                    // Uniform over the other residues.
                    let mut r = rng.below(residues - 1) as u8;
                    if r >= c {
                        r += 1;
                    }
                    r
                }
            };
            out.push(next);
        } else {
            out.push(c);
        }
    }
    if out.is_empty() {
        out.push(0);
    }
    out
}

/// Φ_DNA: one ancestral genome, every sequence a lightly mutated copy
/// (>99% identity, like human mito genomes).
fn mito_genomes(spec: &DatasetSpec, rng: &mut Rng) -> Vec<Sequence> {
    let alphabet = Alphabet::Dna;
    let ancestor = random_residues(spec.base_len, alphabet, rng);
    (0..spec.count)
        .map(|i| {
            let mut r = rng.fork(i as u64);
            let codes = if i == 0 {
                ancestor.clone() // keep one pristine copy (center candidate)
            } else {
                mutate(&ancestor, alphabet, spec.sub_rate, spec.indel_rate, &mut r, None)
            };
            Sequence::new(format!("mito_{i:06}"), codes, alphabet)
        })
        .collect()
}

/// Φ_RNA: a few deep clades, then per-sequence mutation — more divergence
/// and length variation than mito.
fn rrna_family(spec: &DatasetSpec, rng: &mut Rng) -> Vec<Sequence> {
    let alphabet = Alphabet::Dna;
    let root = random_residues(spec.base_len, alphabet, rng);
    let n_clades = 6.min(spec.count.max(1));
    let clades: Vec<Vec<u8>> = (0..n_clades)
        .map(|_| mutate(&root, alphabet, spec.sub_rate, spec.indel_rate, rng, None))
        .collect();
    (0..spec.count)
        .map(|i| {
            let mut r = rng.fork(i as u64 ^ 0xBEEF);
            let clade = &clades[i % n_clades];
            let codes =
                mutate(clade, alphabet, spec.sub_rate / 2.0, spec.indel_rate, &mut r, None);
            Sequence::new(format!("rrna_{i:06}"), codes, alphabet)
        })
        .collect()
}

/// Φ_Protein: families of related proteins; family sizes and lengths vary
/// (19 aa up to ~4x base), substitutions BLOSUM-weighted.
fn protein_families(spec: &DatasetSpec, rng: &mut Rng) -> Vec<Sequence> {
    let alphabet = Alphabet::Protein;
    let weights = substitution_matrix(alphabet);
    let mut out = Vec::with_capacity(spec.count);
    let mut fam = 0usize;
    while out.len() < spec.count {
        // Family size 4..40, length 19..~4x base (BAliBASE-ish long tail).
        let fam_size = 4 + rng.below(37);
        let len = match rng.below(10) {
            0 => 19 + rng.below(40),
            9 => spec.base_len * 2 + rng.below(spec.base_len * 2 + 1),
            _ => spec.base_len / 2 + rng.below(spec.base_len.max(1)),
        }
        .max(19);
        let ancestor = random_residues(len, alphabet, rng);
        for k in 0..fam_size {
            if out.len() >= spec.count {
                break;
            }
            let mut r = rng.fork((fam * 1000 + k) as u64);
            let codes = mutate(
                &ancestor,
                alphabet,
                spec.sub_rate,
                spec.indel_rate,
                &mut r,
                Some(&weights),
            );
            out.push(Sequence::new(format!("prot_f{fam:04}_{k:02}"), codes, alphabet));
        }
        fam += 1;
    }
    out
}

/// Fraction of identical positions between two sequences walked in step —
/// a cheap similarity proxy used by tests.
pub fn identity_fraction(a: &[u8], b: &[u8]) -> f64 {
    let n = a.len().min(b.len());
    if n == 0 {
        return 0.0;
    }
    let same = (0..n).filter(|&i| a[i] == b[i]).count();
    same as f64 / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    /// k-mer containment |A∩B|/|A| — indel-robust similarity proxy.
    fn kmer_containment(a: &[u8], b: &[u8], k: usize) -> f64 {
        use crate::util::hash::DetHashSet;
        let set = |s: &[u8]| -> DetHashSet<Vec<u8>> {
            s.windows(k).map(|w| w.to_vec()).collect()
        };
        let (sa, sb) = (set(a), set(b));
        if sa.is_empty() {
            return 0.0;
        }
        sa.iter().filter(|w| sb.contains(*w)).count() as f64 / sa.len() as f64
    }

    #[test]
    fn mito_is_ultra_similar_and_right_sized() {
        let spec = DatasetSpec { count: 20, ..DatasetSpec::mito(0.02, 1) };
        let seqs = spec.generate();
        assert_eq!(seqs.len(), 20);
        let base = &seqs[0];
        for s in &seqs[1..] {
            assert!((s.len() as i64 - base.len() as i64).unsigned_abs() < 20);
            assert!(
                kmer_containment(&base.codes, &s.codes, 16) > 0.8,
                "mito must stay highly similar (k-mer containment)"
            );
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let spec = DatasetSpec { count: 10, ..DatasetSpec::rrna(10, 0.05, 7) };
        assert_eq!(spec.generate(), spec.generate());
    }

    #[test]
    fn different_seeds_differ() {
        let a = DatasetSpec { count: 5, ..DatasetSpec::mito(0.01, 1) }.generate();
        let b = DatasetSpec { count: 5, ..DatasetSpec::mito(0.01, 2) }.generate();
        assert_ne!(a, b);
    }

    #[test]
    fn rrna_more_divergent_than_mito() {
        let mito = DatasetSpec { count: 12, ..DatasetSpec::mito(0.03, 3) }.generate();
        let rrna = DatasetSpec::rrna(12, 0.3, 3).generate();
        let avg = |seqs: &[Sequence]| {
            let mut total = 0.0;
            let mut n = 0;
            for i in 0..seqs.len() {
                for j in (i + 1)..seqs.len() {
                    total += identity_fraction(&seqs[i].codes, &seqs[j].codes);
                    n += 1;
                }
            }
            total / n as f64
        };
        assert!(avg(&mito) > avg(&rrna), "rRNA should be more divergent");
    }

    #[test]
    fn protein_lengths_have_spread_and_minimum() {
        let seqs = DatasetSpec::protein(200, 0.3, 5).generate();
        assert_eq!(seqs.len(), 200);
        let lens: Vec<usize> = seqs.iter().map(Sequence::len).collect();
        assert!(lens.iter().all(|&l| l >= 19));
        let min = lens.iter().min().unwrap();
        let max = lens.iter().max().unwrap();
        assert!(max > &(min * 2), "length spread expected: {min}..{max}");
    }

    #[test]
    fn protein_alphabet_in_range() {
        let seqs = DatasetSpec::protein(30, 0.1, 6).generate();
        for s in &seqs {
            assert!(s.codes.iter().all(|&c| c < 20), "only residue codes");
        }
    }

    #[test]
    fn scale_multiplies_count_with_fresh_seed() {
        let base = DatasetSpec { count: 8, ..DatasetSpec::mito(0.01, 9) };
        let scaled = base.scale(3);
        assert_eq!(scaled.count, 24);
        assert_ne!(scaled.seed, base.seed);
        assert_eq!(scaled.generate().len(), 24);
    }

    #[test]
    fn generate_range_slices() {
        let spec = DatasetSpec { count: 30, ..DatasetSpec::mito(0.01, 4) };
        let all = spec.generate();
        let mid = spec.generate_range(10, 20);
        assert_eq!(mid[..], all[10..20]);
    }
}

//! FASTA reading/writing (plain text, wrapped at 70 columns).

use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{Alphabet, Sequence};

/// Parse FASTA from any reader.
pub fn read_fasta(reader: impl Read, alphabet: Alphabet) -> Result<Vec<Sequence>> {
    let mut out = Vec::new();
    let mut id: Option<String> = None;
    let mut codes: Vec<u8> = Vec::new();
    for (lineno, line) in BufReader::new(reader).lines().enumerate() {
        let line = line.with_context(|| format!("reading FASTA line {}", lineno + 1))?;
        let line = line.trim_end();
        if line.is_empty() {
            continue;
        }
        if let Some(header) = line.strip_prefix('>') {
            if let Some(prev) = id.take() {
                out.push(Sequence::new(prev, std::mem::take(&mut codes), alphabet));
            }
            id = Some(header.split_whitespace().next().unwrap_or(header).to_string());
        } else {
            if id.is_none() {
                bail!("FASTA line {} has residues before any '>' header", lineno + 1);
            }
            codes.extend(line.bytes().map(|b| alphabet.encode(b)));
        }
    }
    if let Some(prev) = id {
        out.push(Sequence::new(prev, codes, alphabet));
    }
    Ok(out)
}

pub fn read_fasta_file(path: impl AsRef<Path>, alphabet: Alphabet) -> Result<Vec<Sequence>> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {}", path.as_ref().display()))?;
    read_fasta(f, alphabet)
}

/// Write FASTA, 70 columns per line.
pub fn write_fasta(writer: impl Write, seqs: &[Sequence]) -> Result<()> {
    let mut w = BufWriter::new(writer);
    for s in seqs {
        writeln!(w, ">{}", s.id)?;
        let text = s.text();
        for chunk in text.as_bytes().chunks(70) {
            w.write_all(chunk)?;
            w.write_all(b"\n")?;
        }
    }
    w.flush()?;
    Ok(())
}

pub fn write_fasta_file(path: impl AsRef<Path>, seqs: &[Sequence]) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {}", path.as_ref().display()))?;
    write_fasta(f, seqs)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = ">seq1 human mito\nACGTAC\nGTN\n>seq2\nTTTT\n";

    #[test]
    fn parses_multi_record() {
        let seqs = read_fasta(SAMPLE.as_bytes(), Alphabet::Dna).unwrap();
        assert_eq!(seqs.len(), 2);
        assert_eq!(seqs[0].id, "seq1"); // first token only
        assert_eq!(seqs[0].text(), "ACGTACGTN");
        assert_eq!(seqs[1].text(), "TTTT");
    }

    #[test]
    fn roundtrip_through_bytes() {
        let seqs = read_fasta(SAMPLE.as_bytes(), Alphabet::Dna).unwrap();
        let mut buf = Vec::new();
        write_fasta(&mut buf, &seqs).unwrap();
        let back = read_fasta(&buf[..], Alphabet::Dna).unwrap();
        assert_eq!(back, seqs);
    }

    #[test]
    fn long_sequences_wrap() {
        let s = Sequence::from_text("x", &"A".repeat(200), Alphabet::Dna);
        let mut buf = Vec::new();
        write_fasta(&mut buf, &[s.clone()]).unwrap();
        let text = String::from_utf8(buf.clone()).unwrap();
        assert!(text.lines().all(|l| l.len() <= 70));
        assert_eq!(read_fasta(&buf[..], Alphabet::Dna).unwrap()[0], s);
    }

    #[test]
    fn rejects_headerless_residues() {
        assert!(read_fasta("ACGT\n".as_bytes(), Alphabet::Dna).is_err());
    }

    #[test]
    fn empty_input_ok() {
        assert!(read_fasta("".as_bytes(), Alphabet::Dna).unwrap().is_empty());
    }
}

//! Alphabets and the byte<->code mappings shared with the python kernels.

use anyhow::{bail, Result};

/// DNA codes: A=0 C=1 G=2 T/U=3 N=4 gap=5 sentinel=6.  The sentinel is
/// a dedicated padding code — it must never collide with the gap code,
/// or batcher padding becomes indistinguishable from real gap columns.
pub const DNA_ALPHA: usize = 7;
pub const PROTEIN_ALPHA: usize = 25;

/// Canonical amino-acid order for codes 0..19.
pub const AMINO_ACIDS: &[u8; 20] = b"ARNDCQEGHILKMFPSTWYV";

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Alphabet {
    Dna = 0,
    Protein = 1,
}

impl Alphabet {
    pub fn from_u8(v: u8) -> Result<Self> {
        Ok(match v {
            0 => Alphabet::Dna,
            1 => Alphabet::Protein,
            other => bail!("bad alphabet tag {other}"),
        })
    }

    /// Number of codes including gap and sentinel.
    pub fn size(self) -> usize {
        match self {
            Alphabet::Dna => DNA_ALPHA,
            Alphabet::Protein => PROTEIN_ALPHA,
        }
    }

    /// The gap code ('-').
    pub fn gap(self) -> u8 {
        match self {
            Alphabet::Dna => 5,
            Alphabet::Protein => 23,
        }
    }

    /// Padding sentinel used by the XLA batcher (never a real residue).
    pub fn sentinel(self) -> u8 {
        (self.size() - 1) as u8
    }

    /// Unknown-residue code.
    pub fn unknown(self) -> u8 {
        match self {
            Alphabet::Dna => 4,  // N
            Alphabet::Protein => 22, // X
        }
    }

    /// Number of *residue* codes (excluding gap/sentinel) — what the
    /// dataset generators draw from.
    pub fn residues(self) -> usize {
        match self {
            Alphabet::Dna => 4,
            Alphabet::Protein => 20,
        }
    }

    pub fn encode(self, b: u8) -> u8 {
        match self {
            Alphabet::Dna => match b.to_ascii_uppercase() {
                b'A' => 0,
                b'C' => 1,
                b'G' => 2,
                b'T' | b'U' => 3,
                b'-' | b'.' => 5,
                _ => 4, // N and all ambiguity codes
            },
            Alphabet::Protein => match b.to_ascii_uppercase() {
                b'-' | b'.' => 23,
                b'B' => 20,
                b'Z' => 21,
                up => AMINO_ACIDS
                    .iter()
                    .position(|&a| a == up)
                    .map(|i| i as u8)
                    .unwrap_or(22), // X
            },
        }
    }

    pub fn decode(self, code: u8) -> u8 {
        match self {
            Alphabet::Dna => match code {
                0 => b'A',
                1 => b'C',
                2 => b'G',
                3 => b'T',
                4 => b'N',
                _ => b'-',
            },
            Alphabet::Protein => match code {
                0..=19 => AMINO_ACIDS[code as usize],
                20 => b'B',
                21 => b'Z',
                22 => b'X',
                _ => b'-',
            },
        }
    }
}

/// Flattened substitution matrix (alpha x alpha, row-major f32) for the SW
/// kernels and native DP.
///
/// DNA: +5 match / -4 mismatch (HAlign's defaults); protein: BLOSUM62-like
/// structure — identity-dominant with chemically-similar off-diagonals.
/// Gap and sentinel rows/columns are strongly negative so alignments never
/// extend through padding.
pub fn substitution_matrix(alphabet: Alphabet) -> Vec<f32> {
    let n = alphabet.size();
    let mut m = vec![0f32; n * n];
    match alphabet {
        Alphabet::Dna => {
            for i in 0..4 {
                for j in 0..4 {
                    m[i * n + j] = if i == j { 5.0 } else { -4.0 };
                }
            }
            // N matches anything weakly.
            for i in 0..5 {
                m[i * n + 4] = -1.0;
                m[4 * n + i] = -1.0;
            }
        }
        Alphabet::Protein => {
            // BLOSUM62 upper triangle over the AMINO_ACIDS order.
            const B62: [[i8; 20]; 20] = [
                [4, -1, -2, -2, 0, -1, -1, 0, -2, -1, -1, -1, -1, -2, -1, 1, 0, -3, -2, 0],
                [-1, 5, 0, -2, -3, 1, 0, -2, 0, -3, -2, 2, -1, -3, -2, -1, -1, -3, -2, -3],
                [-2, 0, 6, 1, -3, 0, 0, 0, 1, -3, -3, 0, -2, -3, -2, 1, 0, -4, -2, -3],
                [-2, -2, 1, 6, -3, 0, 2, -1, -1, -3, -4, -1, -3, -3, -1, 0, -1, -4, -3, -3],
                [0, -3, -3, -3, 9, -3, -4, -3, -3, -1, -1, -3, -1, -2, -3, -1, -1, -2, -2, -1],
                [-1, 1, 0, 0, -3, 5, 2, -2, 0, -3, -2, 1, 0, -3, -1, 0, -1, -2, -1, -2],
                [-1, 0, 0, 2, -4, 2, 5, -2, 0, -3, -3, 1, -2, -3, -1, 0, -1, -3, -2, -2],
                [0, -2, 0, -1, -3, -2, -2, 6, -2, -4, -4, -2, -3, -3, -2, 0, -2, -2, -3, -3],
                [-2, 0, 1, -1, -3, 0, 0, -2, 8, -3, -3, -1, -2, -1, -2, -1, -2, -2, 2, -3],
                [-1, -3, -3, -3, -1, -3, -3, -4, -3, 4, 2, -3, 1, 0, -3, -2, -1, -3, -1, 3],
                [-1, -2, -3, -4, -1, -2, -3, -4, -3, 2, 4, -2, 2, 0, -3, -2, -1, -2, -1, 1],
                [-1, 2, 0, -1, -3, 1, 1, -2, -1, -3, -2, 5, -1, -3, -1, 0, -1, -3, -2, -2],
                [-1, -1, -2, -3, -1, 0, -2, -3, -2, 1, 2, -1, 5, 0, -2, -1, -1, -1, -1, 1],
                [-2, -3, -3, -3, -2, -3, -3, -3, -1, 0, 0, -3, 0, 6, -4, -2, -2, 1, 3, -1],
                [-1, -2, -2, -1, -3, -1, -1, -2, -2, -3, -3, -1, -2, -4, 7, -1, -1, -4, -3, -2],
                [1, -1, 1, 0, -1, 0, 0, 0, -1, -2, -2, 0, -1, -2, -1, 4, 1, -3, -2, -2],
                [0, -1, 0, -1, -1, -1, -1, -2, -2, -1, -1, -1, -1, -2, -1, 1, 5, -2, -2, 0],
                [-3, -3, -4, -4, -2, -2, -3, -2, -2, -3, -2, -3, -1, 1, -4, -3, -2, 11, 2, -3],
                [-2, -2, -2, -3, -2, -1, -2, -3, 2, -1, -1, -2, -1, 3, -3, -2, -2, 2, 7, -1],
                [0, -3, -3, -3, -1, -2, -2, -3, -3, 3, 1, -2, 1, -1, -2, -2, 0, -3, -1, 4],
            ];
            for i in 0..20 {
                for j in 0..20 {
                    m[i * n + j] = B62[i][j] as f32;
                }
            }
            // Ambiguity codes: mild penalty against everything.
            for amb in 20..23 {
                for j in 0..23 {
                    m[amb * n + j] = -1.0;
                    m[j * n + amb] = -1.0;
                }
            }
        }
    }
    // Gap + sentinel rows/columns: forbidden in substitution context.
    let gap = alphabet.gap() as usize;
    let sent = alphabet.sentinel() as usize;
    for k in [gap, sent] {
        for j in 0..n {
            m[k * n + j] = -1e4;
            m[j * n + k] = -1e4;
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dna_encode_decode_roundtrip() {
        for b in [b'A', b'C', b'G', b'T', b'N', b'-'] {
            let a = Alphabet::Dna;
            assert_eq!(a.decode(a.encode(b)), b);
        }
    }

    #[test]
    fn protein_all_residues_roundtrip() {
        let a = Alphabet::Protein;
        for &b in AMINO_ACIDS.iter() {
            assert_eq!(a.decode(a.encode(b)), b);
        }
        assert_eq!(a.decode(a.encode(b'-')), b'-');
        assert_eq!(a.encode(b'J'), 22); // unknown -> X
    }

    #[test]
    fn lowercase_accepted() {
        assert_eq!(Alphabet::Dna.encode(b'a'), 0);
        assert_eq!(Alphabet::Protein.encode(b'm'), 12);
    }

    #[test]
    fn blosum_symmetric_and_identity_dominant() {
        let m = substitution_matrix(Alphabet::Protein);
        let n = PROTEIN_ALPHA;
        for i in 0..20 {
            for j in 0..20 {
                assert_eq!(m[i * n + j], m[j * n + i], "({i},{j})");
                if i != j {
                    assert!(m[i * n + i] > m[i * n + j]);
                }
            }
        }
    }

    #[test]
    fn gap_and_sentinel_forbidden() {
        for alpha in [Alphabet::Dna, Alphabet::Protein] {
            let m = substitution_matrix(alpha);
            let n = alpha.size();
            let gap = alpha.gap() as usize;
            let sent = alpha.sentinel() as usize;
            for j in 0..n {
                assert!(m[gap * n + j] <= -1e4);
                assert!(m[sent * n + j] <= -1e4);
                assert!(m[j * n + sent] <= -1e4);
            }
        }
    }

    #[test]
    fn sentinel_distinct_from_gap_for_every_alphabet() {
        // A sentinel==gap collision makes batcher padding look like real
        // gap columns (the old DNA_ALPHA=6 bug); every alphabet must
        // keep the two codes distinct and in range.
        for alpha in [Alphabet::Dna, Alphabet::Protein] {
            assert_ne!(alpha.gap(), alpha.sentinel(), "{alpha:?}");
            assert!((alpha.gap() as usize) < alpha.size(), "{alpha:?}");
            assert!((alpha.sentinel() as usize) < alpha.size(), "{alpha:?}");
            assert_ne!(alpha.unknown(), alpha.gap(), "{alpha:?}");
            assert_ne!(alpha.unknown(), alpha.sentinel(), "{alpha:?}");
        }
        assert_eq!(Alphabet::Dna.gap(), 5);
        assert_eq!(Alphabet::Dna.sentinel(), 6);
    }
}

//! Sequence types, alphabets and FASTA I/O.
//!
//! Integer code spaces (shared with the python kernels — see
//! `python/compile/model.py`):
//!
//! * DNA/RNA: `A=0 C=1 G=2 T/U=3 N=4 gap=5` padding sentinel `6`
//!   (`DNA_ALPHA = 7` — gap and sentinel are distinct codes)
//! * Protein: 20 amino acids `ARNDCQEGHILKMFPSTWYV = 0..19`, ambiguity
//!   `B=20 Z=21 X=22`, gap `23`, padding sentinel `24` (`PROTEIN_ALPHA=25`)

pub mod alphabet;
pub mod io;

pub use alphabet::{Alphabet, DNA_ALPHA, PROTEIN_ALPHA};

/// A named biological sequence with its integer-coded residues.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Sequence {
    pub id: String,
    pub codes: Vec<u8>,
    pub alphabet: Alphabet,
}

impl Sequence {
    pub fn new(id: impl Into<String>, codes: Vec<u8>, alphabet: Alphabet) -> Self {
        Self { id: id.into(), codes, alphabet }
    }

    /// Parse residue text (e.g. "ACGT") under the given alphabet.
    pub fn from_text(id: impl Into<String>, text: &str, alphabet: Alphabet) -> Self {
        let codes = text.bytes().map(|b| alphabet.encode(b)).collect();
        Self::new(id, codes, alphabet)
    }

    pub fn len(&self) -> usize {
        self.codes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.codes.is_empty()
    }

    /// Residue text (gaps render as '-').
    pub fn text(&self) -> String {
        self.codes.iter().map(|&c| self.alphabet.decode(c) as char).collect()
    }

    /// Approximate resident bytes (id + codes) for memory accounting.
    pub fn approx_bytes(&self) -> usize {
        self.id.len() + self.codes.len() + 48
    }
}

impl crate::util::Encode for Sequence {
    fn encode(&self, out: &mut Vec<u8>) {
        self.id.encode(out);
        self.codes.encode(out);
        (self.alphabet as u8).encode(out);
    }
}

impl crate::util::Decode for Sequence {
    fn decode(input: &mut &[u8]) -> anyhow::Result<Self> {
        let id = String::decode(input)?;
        let codes = Vec::<u8>::decode(input)?;
        let alphabet = Alphabet::from_u8(u8::decode(input)?)?;
        Ok(Self { id, codes, alphabet })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{Decode, Encode};

    #[test]
    fn text_roundtrip_dna() {
        let s = Sequence::from_text("s1", "ACGTN-", Alphabet::Dna);
        assert_eq!(s.codes, vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(s.text(), "ACGTN-");
    }

    #[test]
    fn rna_u_maps_to_t_code() {
        let s = Sequence::from_text("r", "ACGU", Alphabet::Dna);
        assert_eq!(s.codes, vec![0, 1, 2, 3]);
    }

    #[test]
    fn codec_roundtrip() {
        let s = Sequence::from_text("seq with spaces", "MKV", Alphabet::Protein);
        let back = Sequence::from_bytes(&s.to_bytes()).unwrap();
        assert_eq!(back, s);
    }
}

//! Benchmark harness regenerating every table and figure of the paper's
//! evaluation (DESIGN.md §5).  Shared by `halign2 bench-table ...` and the
//! `rust/benches/*.rs` targets.
//!
//! Scaling: the paper's absolute dataset sizes (up to 15 GB / 17.8M
//! sequences) don't fit a CI box; every workload here is the paper's
//! *composition* at a configurable scale (default ≈1/10th counts and
//! 1/10th genome length), and the claims checked are the relative ones —
//! who wins, by what factor, who DNFs — as recorded in EXPERIMENTS.md.
//! `--scale` raises the tiers toward paper scale on bigger machines.
//!
//! DNF handling: single-node baselines carry a *probe-and-extrapolate*
//! guard — each runs on a small probe slice first, its full cost is
//! extrapolated from the tool's complexity model, and runs whose estimate
//! exceeds the time budget are recorded as DNF ("> budget"), mirroring
//! the paper's "-" and "> 24 h" entries without burning hours.

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::align::center_star::{align_nucleotide, CenterStarConfig};
use crate::align::protein::{align_protein, ProteinConfig};
use crate::align::KernelBackend;
use crate::baselines::progressive::{estimated_bytes, progressive_msa, ProgressiveConfig};
use crate::baselines::{halign_v1, hptree_build, iqtree_like, sparksw};
use crate::data::DatasetSpec;
use crate::engine::{Cluster, ClusterConfig, SchedulerMode};
use crate::fasta::Sequence;
use crate::metrics::RunReport;
use crate::obs::{Profile, TraceKind};
use crate::runtime::XlaService;
use crate::distmat::DistBackend;
use crate::tree::{build_tree, ClusterConfig as TreeClusterConfig, DistMatOptions, TreeConfig};

#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub workers: usize,
    /// Multiplies every dataset tier's sequence count (1.0 = defaults).
    pub scale: f64,
    /// Per-cell time budget; estimated-over-budget rows record DNF.
    pub budget: Duration,
    /// Quick mode shrinks tiers further (CI smoke).
    pub quick: bool,
    pub seed: u64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            workers: 8,
            scale: 1.0,
            budget: Duration::from_secs(120),
            quick: false,
            seed: 0xBEEF,
        }
    }
}

impl BenchConfig {
    fn count(&self, base: usize) -> usize {
        let c = (base as f64 * self.scale) as usize;
        if self.quick {
            (c / 8).max(8)
        } else {
            c.max(8)
        }
    }

    /// Φ_DNA tiers: (label, spec). Counts: 168/1680/6720 at scale 1
    /// (paper: 672/67k/672k), genome length 1/10th (1.66 kb).
    pub fn dna_tiers(&self) -> Vec<(String, DatasetSpec)> {
        let base = DatasetSpec {
            count: 0,
            ..DatasetSpec::mito(if self.quick { 0.02 } else { 0.1 }, self.seed)
        };
        [("dna_1x", 168), ("dna_20x", 1680), ("dna_80x", 6720)]
            .into_iter()
            .map(|(l, c)| (l.to_string(), DatasetSpec { count: self.count(c), ..base.clone() }))
            .collect()
    }

    /// Φ_RNA tiers (paper: 108k/1M at ~1.4 kb).
    pub fn rna_tiers(&self) -> Vec<(String, DatasetSpec)> {
        let ls = if self.quick { 0.05 } else { 0.5 };
        vec![
            ("rna_small".into(), DatasetSpec::rrna(self.count(1200), ls, self.seed ^ 1)),
            ("rna_large".into(), DatasetSpec::rrna(self.count(6000), ls, self.seed ^ 2)),
        ]
    }

    /// Φ_Protein tiers (paper: 17.9k/1.79M/17.9M, avg 459 aa).
    pub fn protein_tiers(&self) -> Vec<(String, DatasetSpec)> {
        let ls = if self.quick { 0.15 } else { 0.6 };
        [("prot_1x", 600), ("prot_10x", 3000), ("prot_40x", 12000)]
            .into_iter()
            .map(|(l, c)| (l.to_string(), DatasetSpec::protein(self.count(c), ls, self.seed ^ 3)))
            .collect()
    }
}

/// Time a run and fold in the engine stats.
pub fn measure<T>(
    tool: &str,
    dataset: &str,
    metric_name: &'static str,
    f: impl FnOnce() -> Result<(T, Option<f64>, Option<Cluster>)>,
) -> RunReport {
    let start = Instant::now();
    match f() {
        Ok((_, metric, engine)) => {
            let mut r = RunReport {
                tool: tool.into(),
                dataset: dataset.into(),
                wall: start.elapsed(),
                busy: None,
                metric,
                metric_name,
                avg_max_memory_mb: None,
                shuffle_mb: None,
                busy_skew: None,
                tasks_stolen: None,
                steal_batches: None,
                lock_contentions: None,
                speculative_launches: None,
                distmat_peak_mb: None,
                p50_ms: None,
                p99_ms: None,
                dnf: None,
            };
            if let Some(engine) = engine {
                r = r.with_stats(&engine.stats());
            }
            r
        }
        Err(e) => RunReport::dnf(tool, dataset, format!("{e}").chars().take(40).collect::<String>()),
    }
}

/// Probe-and-extrapolate guard for a superlinear single-node tool:
/// runs `f` on `probe` sequences, extrapolates with `cost(n)` and
/// returns Err when the estimate blows the budget.
fn guard_budget(
    seqs: &[Sequence],
    probe_n: usize,
    budget: Duration,
    cost: impl Fn(usize) -> f64,
    probe_run: impl Fn(&[Sequence]) -> Result<()>,
) -> Result<()> {
    if seqs.len() <= probe_n {
        return Ok(());
    }
    let probe = &seqs[..probe_n];
    let t0 = Instant::now();
    probe_run(probe)?;
    let probe_time = t0.elapsed().as_secs_f64().max(1e-3);
    let est = probe_time * cost(seqs.len()) / cost(probe_n);
    if est > budget.as_secs_f64() {
        anyhow::bail!("> budget (est {est:.0}s)");
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Machine-readable bench telemetry
// ---------------------------------------------------------------------------

/// Write `BENCH_<scenario>.json` at the repo root, next to the committed
/// `BENCH_<scenario>.baseline.json` that `scripts/bench_compare.py` diffs
/// it against.  The scenario and every key must be string literals at the
/// call site: pallas-lint W9 cross-checks them against the baseline's key
/// set, so a new key can only land together with its baseline row.
/// Best-effort on purpose — a bench run from a read-only checkout prints
/// its table and just warns about the JSON.
pub fn write_bench_json(scenario: &str, fields: &[(&str, String)]) {
    let mut json = format!("{{\n  \"bench\": \"{scenario}\",\n");
    for (i, (k, v)) in fields.iter().enumerate() {
        let comma = if i + 1 < fields.len() { "," } else { "" };
        json.push_str(&format!("  \"{k}\": {v}{comma}\n"));
    }
    json.push_str("}\n");
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap_or_else(|| std::path::Path::new("."))
        .join(format!("BENCH_{scenario}.json"));
    match std::fs::write(&path, json) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("warning: could not write {}: {e}", path.display()),
    }
}

/// Scheduler counters + critical-path fraction distilled from one traced
/// job's drained rings — the host-independent numbers every
/// `BENCH_*.json` scenario section reports.
struct TraceTelemetry {
    tasks: u64,
    steals: u64,
    speculative_launches: u64,
    kill_drained: u64,
    critical_path_frac: f64,
    wall_secs: f64,
}

impl TraceTelemetry {
    fn from_cluster(engine: &Cluster, wall_secs: f64) -> TraceTelemetry {
        let events = engine.trace().drain_new();
        let count = |kind: TraceKind| events.iter().filter(|e| e.kind == kind).count() as u64;
        let profile = Profile::from_events(&events, engine.trace().num_lanes());
        TraceTelemetry {
            tasks: count(TraceKind::Finish),
            steals: count(TraceKind::Steal),
            speculative_launches: count(TraceKind::SpeculativeLaunch),
            kill_drained: count(TraceKind::KillDrain),
            critical_path_frac: profile.critical_path_frac,
            wall_secs,
        }
    }
}

/// Run `f` on a fresh traced cluster and distill its rings.
fn traced_telemetry(
    workers: usize,
    f: impl FnOnce(&Cluster) -> Result<()>,
) -> Result<TraceTelemetry> {
    let mut ccfg = ClusterConfig::spark(workers);
    ccfg.scheduler.trace_capacity = 1 << 14;
    let engine = Cluster::new(ccfg);
    let t0 = Instant::now();
    f(&engine)?;
    Ok(TraceTelemetry::from_cluster(&engine, t0.elapsed().as_secs_f64()))
}

// ---------------------------------------------------------------------------
// Tables
// ---------------------------------------------------------------------------

/// Table 2 — genome MSA: MUSCLE/MAFFT-like progressive, HAlign (Hadoop),
/// HAlign-II (Spark). Metric: avg SP (penalty, lower = better).
pub fn table2_genome(cfg: &BenchConfig) -> Vec<RunReport> {
    let mut out = Vec::new();
    for (label, spec) in cfg.dna_tiers() {
        let seqs = spec.generate();
        // Progressive (single-node MUSCLE/MAFFT stand-in).
        let pcfg = ProgressiveConfig::default();
        let alpha = seqs[0].alphabet.residues();
        let lmax = seqs.iter().map(Sequence::len).max().unwrap();
        let oom = estimated_bytes(seqs.len(), lmax, alpha, &pcfg) > pcfg.memory_budget;
        if oom {
            out.push(RunReport::dnf("progressive", &label, "OOM"));
        } else {
            let guard = guard_budget(
                &seqs,
                12.min(seqs.len()),
                cfg.budget,
                |n| (n * n) as f64 * (lmax * lmax) as f64,
                |probe| progressive_msa(probe, &pcfg).map(|_| ()),
            );
            match guard {
                Err(e) => out.push(RunReport::dnf("progressive", &label, format!("{e}"))),
                Ok(()) => out.push(measure("progressive", &label, "avgSP", || {
                    let msa = progressive_msa(&seqs, &pcfg)?;
                    let sp = msa.avg_sp()?;
                    Ok((msa, Some(sp), None))
                })),
            }
        }
        // HAlign v1 (Hadoop).
        out.push(measure("halign_v1", &label, "avgSP", || {
            let (msa, engine) = halign_v1::halign_v1_msa(
                cfg.workers,
                &seqs,
                &CenterStarConfig::default(),
            )?;
            let sp = msa.avg_sp_distributed(&engine)?;
            Ok((msa, Some(sp), Some(engine)))
        }));
        // HAlign-II (Spark).
        out.push(measure("halign2", &label, "avgSP", || {
            let engine = Cluster::new(ClusterConfig::spark(cfg.workers));
            let msa = align_nucleotide(&engine, &seqs, &CenterStarConfig::default())?;
            let sp = msa.avg_sp_distributed(&engine)?;
            Ok((msa, Some(sp), Some(engine)))
        }));
    }

    // Machine-readable section: re-run the smallest tier traced so the
    // scheduler counters and critical-path fraction come from real
    // rings; the v1-vs-v2 SP agreement is the correctness flag.
    let sp_match = {
        let tier = |tool: &str| {
            out.iter().find(|r| r.tool == tool && r.dnf.is_none()).and_then(|r| r.metric)
        };
        tier("halign_v1") == tier("halign2") && tier("halign2").is_some()
    };
    if let Some((_, spec)) = cfg.dna_tiers().into_iter().next() {
        let seqs = spec.generate();
        let tel = traced_telemetry(cfg.workers, |engine| {
            align_nucleotide(engine, &seqs, &CenterStarConfig::default()).map(|_| ())
        });
        if let Ok(tel) = tel {
            let throughput = seqs.len() as f64 / tel.wall_secs.max(1e-9);
            write_bench_json(
                "table2",
                &[
                    ("sp_match", sp_match.to_string()),
                    ("tasks_run", tel.tasks.to_string()),
                    ("steals", tel.steals.to_string()),
                    ("speculative_launches", tel.speculative_launches.to_string()),
                    ("kill_drained", tel.kill_drained.to_string()),
                    ("critical_path_frac", format!("{:.6}", tel.critical_path_frac)),
                    ("throughput_seqs_per_sec", format!("{throughput:.3}")),
                    ("wall_secs", format!("{:.6}", tel.wall_secs)),
                ],
            );
        }
    }
    out
}

/// Table 3 — RNA MSA (same tool set as Table 2, divergent sequences).
pub fn table3_rna(cfg: &BenchConfig) -> Vec<RunReport> {
    let mut out = Vec::new();
    for (label, spec) in cfg.rna_tiers() {
        let seqs = spec.generate();
        let pcfg = ProgressiveConfig::default();
        let lmax = seqs.iter().map(Sequence::len).max().unwrap();
        let alpha = seqs[0].alphabet.residues();
        if estimated_bytes(seqs.len(), lmax, alpha, &pcfg) > pcfg.memory_budget {
            out.push(RunReport::dnf("progressive", &label, "OOM"));
        } else {
            match guard_budget(
                &seqs,
                10.min(seqs.len()),
                cfg.budget,
                |n| (n * n) as f64 * (lmax * lmax) as f64,
                |probe| progressive_msa(probe, &pcfg).map(|_| ()),
            ) {
                Err(e) => out.push(RunReport::dnf("progressive", &label, format!("{e}"))),
                Ok(()) => out.push(measure("progressive", &label, "avgSP", || {
                    let msa = progressive_msa(&seqs, &pcfg)?;
                    let sp = msa.avg_sp()?;
                    Ok((msa, Some(sp), None))
                })),
            }
        }
        let cs_cfg = CenterStarConfig { segment_len: 10, ..Default::default() };
        out.push(measure("halign_v1", &label, "avgSP", || {
            let (msa, engine) = halign_v1::halign_v1_msa(cfg.workers, &seqs, &cs_cfg)?;
            let sp = msa.avg_sp_distributed(&engine)?;
            Ok((msa, Some(sp), Some(engine)))
        }));
        out.push(measure("halign2", &label, "avgSP", || {
            let engine = Cluster::new(ClusterConfig::spark(cfg.workers));
            let msa = align_nucleotide(&engine, &seqs, &cs_cfg)?;
            let sp = msa.avg_sp_distributed(&engine)?;
            Ok((msa, Some(sp), Some(engine)))
        }));
    }
    out
}

/// Table 4 — protein MSA: progressive, SparkSW, HAlign-II (XLA-batched
/// SW when a service is supplied).
pub fn table4_protein(cfg: &BenchConfig, svc: Option<&XlaService>) -> Vec<RunReport> {
    let mut out = Vec::new();
    for (label, spec) in cfg.protein_tiers() {
        let seqs = spec.generate();
        let pcfg = ProgressiveConfig::default();
        let lmax = seqs.iter().map(Sequence::len).max().unwrap();
        if estimated_bytes(seqs.len(), lmax, 20, &pcfg) > pcfg.memory_budget {
            out.push(RunReport::dnf("progressive", &label, "OOM"));
        } else {
            match guard_budget(
                &seqs,
                10.min(seqs.len()),
                cfg.budget,
                |n| (n * n) as f64 * (lmax * lmax) as f64,
                |probe| progressive_msa(probe, &pcfg).map(|_| ()),
            ) {
                Err(e) => out.push(RunReport::dnf("progressive", &label, format!("{e}"))),
                Ok(()) => out.push(measure("progressive", &label, "avgSP", || {
                    let msa = progressive_msa(&seqs, &pcfg)?;
                    let sp = msa.avg_sp()?;
                    Ok((msa, Some(sp), None))
                })),
            }
        }
        // SparkSW — guard: full-matrix SW per pair; cost ~ n * lmax^2.
        match guard_budget(
            &seqs,
            24.min(seqs.len()),
            cfg.budget,
            |n| n as f64,
            |probe| sparksw::sparksw_msa(cfg.workers, probe, 5.0).map(|_| ()),
        ) {
            Err(e) => out.push(RunReport::dnf("sparksw", &label, format!("{e}"))),
            Ok(()) => out.push(measure("sparksw", &label, "avgSP", || {
                let (msa, engine) = sparksw::sparksw_msa(cfg.workers, &seqs, 5.0)?;
                let sp = msa.avg_sp_distributed(&engine)?;
                Ok((msa, Some(sp), Some(engine)))
            })),
        }
        out.push(measure("halign2", &label, "avgSP", || {
            let engine = Cluster::new(ClusterConfig::spark(cfg.workers));
            let msa = align_protein(&engine, &seqs, svc, &ProteinConfig::default())?;
            let sp = msa.avg_sp_distributed(&engine)?;
            Ok((msa, Some(sp), Some(engine)))
        }));
    }
    out
}

/// Table 5 — phylogenetic tree construction over the MSA outputs:
/// IQ-TREE-like ML search, HPTree (Hadoop NJ), HAlign-II (Spark NJ).
/// Metric: JC69 logML of the produced tree.
pub fn table5_tree(cfg: &BenchConfig, svc: Option<&XlaService>) -> Vec<RunReport> {
    let mut out = Vec::new();
    let tree_cfg = TreeConfig {
        clustering: TreeClusterConfig { max_cluster_size: 96, ..Default::default() },
        ..Default::default()
    };
    // One dataset per family (the full 8-row sweep is the bench target's
    // --full mode; wall-clock dominated by the MSA step otherwise).
    let mut jobs: Vec<(String, Vec<Sequence>)> = Vec::new();
    for (label, spec) in cfg.dna_tiers().into_iter().take(2) {
        let seqs = spec.generate();
        let engine = Cluster::new(ClusterConfig::spark(cfg.workers));
        let msa = align_nucleotide(&engine, &seqs, &CenterStarConfig::default())
            .expect("MSA for tree bench");
        jobs.push((label, msa.aligned));
    }
    for (label, spec) in cfg.protein_tiers().into_iter().take(1) {
        let seqs = spec.generate();
        let engine = Cluster::new(ClusterConfig::spark(cfg.workers));
        let msa = align_protein(&engine, &seqs, svc, &ProteinConfig::default())
            .expect("protein MSA for tree bench");
        jobs.push((label, msa.aligned));
    }

    for (label, rows) in &jobs {
        let is_protein = rows[0].alphabet == crate::fasta::Alphabet::Protein;
        // IQ-TREE-like: ML search is O(rounds * edges * n * width) — guard.
        match guard_budget(
            rows,
            16.min(rows.len()),
            cfg.budget,
            |n| (n * n * n) as f64,
            |probe| {
                iqtree_like::iqtree_like_search(probe, &iqtree_like::IqTreeConfig::default())
                    .map(|_| ())
            },
        ) {
            Err(e) => out.push(RunReport::dnf("iqtree_like", label, format!("{e}"))),
            Ok(()) => out.push(measure("iqtree_like", label, "logML", || {
                let r = iqtree_like::iqtree_like_search(
                    rows,
                    &iqtree_like::IqTreeConfig::default(),
                )?;
                Ok(((), Some(r.log_likelihood), None))
            })),
        }
        // HPTree (no protein support).
        if is_protein {
            out.push(RunReport::dnf("hptree", label, "not supported"));
        } else {
            out.push(measure("hptree", label, "logML", || {
                let (r, engine) = hptree_build(cfg.workers, rows, &tree_cfg)?;
                Ok(((), Some(r.log_likelihood), Some(engine)))
            }));
        }
        // HAlign-II.
        out.push(measure("halign2", label, "logML", || {
            let engine = Cluster::new(ClusterConfig::spark(cfg.workers));
            let r = build_tree(&engine, rows, svc, &tree_cfg)?;
            Ok(((), Some(r.log_likelihood), Some(engine)))
        }));
    }

    // Distmat A/B: the same tree, dense vs tiled distance backend, at
    // 16/32/64 simulated workers (tiles are the stealable unit, so tile
    // jobs scale with workers while results stay bit-identical).  The
    // distmat_peak_mb column is the headline: dense reports the largest
    // cluster's O(n²) matrices, tiled stays under its byte budget.
    let mut dense_peak_bytes = 0u64;
    let mut tiled_peak_bytes = 0u64;
    let mut backends_agree = true;
    if let Some((label, rows)) = jobs.first() {
        let tile_rows = if cfg.quick { 6 } else { 24 };
        let byte_budget: usize = 16 * tile_rows * tile_rows * 8;
        for workers in [16usize, 32, 64] {
            for (tool, backend) in [
                ("halign2_dense", DistBackend::Dense),
                ("halign2_tiled", DistBackend::Tiled { tile_rows, byte_budget }),
            ] {
                let name = format!("{label}@w{workers}");
                let peak_mb = std::cell::Cell::new(None);
                let peak_bytes = std::cell::Cell::new(0u64);
                let tcfg = TreeConfig {
                    clustering: tree_cfg.clustering.clone(),
                    distmat: DistMatOptions { backend },
                    ..Default::default()
                };
                let mut r = measure(tool, &name, "logML", || {
                    let engine = Cluster::new(ClusterConfig::spark(workers));
                    // No XLA here: the tiled backend always computes
                    // natively, so the dense side must too for the
                    // bit-identical A/B to hold.
                    let res = build_tree(&engine, rows, None, &tcfg)?;
                    peak_mb
                        .set(Some(res.distmat_peak_bytes as f64 / (1u64 << 20) as f64));
                    peak_bytes.set(res.distmat_peak_bytes as u64);
                    Ok(((), Some(res.log_likelihood), Some(engine)))
                });
                r.distmat_peak_mb = peak_mb.get();
                match tool {
                    "halign2_dense" => {
                        dense_peak_bytes = dense_peak_bytes.max(peak_bytes.get());
                    }
                    _ => tiled_peak_bytes = tiled_peak_bytes.max(peak_bytes.get()),
                }
                out.push(r);
            }
            let pair = &out[out.len() - 2..];
            backends_agree &= pair[0].metric == pair[1].metric
                && pair.iter().all(|r| r.dnf.is_none());
        }

        // Machine-readable section: one extra traced tiled run supplies
        // the scheduler counters and critical-path fraction; the
        // dense/tiled peak-bytes ratio is the headline the gate caps.
        let tcfg = TreeConfig {
            clustering: tree_cfg.clustering.clone(),
            distmat: DistMatOptions {
                backend: DistBackend::Tiled { tile_rows, byte_budget },
            },
            ..Default::default()
        };
        let tel = traced_telemetry(cfg.workers, |engine| {
            build_tree(engine, rows, None, &tcfg).map(|_| ())
        });
        if let Ok(tel) = tel {
            let ratio = tiled_peak_bytes as f64 / dense_peak_bytes.max(1) as f64;
            let throughput = rows.len() as f64 / tel.wall_secs.max(1e-9);
            write_bench_json(
                "table5",
                &[
                    ("distmat_peak_bytes_dense", dense_peak_bytes.to_string()),
                    ("distmat_peak_bytes_tiled", tiled_peak_bytes.to_string()),
                    ("peak_bytes_ratio", format!("{ratio:.6}")),
                    ("backends_agree", backends_agree.to_string()),
                    ("tasks_run", tel.tasks.to_string()),
                    ("steals", tel.steals.to_string()),
                    ("speculative_launches", tel.speculative_launches.to_string()),
                    ("kill_drained", tel.kill_drained.to_string()),
                    ("critical_path_frac", format!("{:.6}", tel.critical_path_frac)),
                    ("throughput_rows_per_sec", format!("{throughput:.3}")),
                    ("wall_secs", format!("{:.6}", tel.wall_secs)),
                ],
            );
        }
    }
    out
}

/// Exact-kernel A/B — the same center-star MSA with the scalar f32
/// pairwise kernels vs the integer bit-parallel/banded kernels.  The
/// integer path is certified-equal to the full DP (not an
/// approximation), so the avg-SP metric must be bit-identical; the only
/// columns allowed to differ are time-shaped.
pub fn kernel_ab(cfg: &BenchConfig) -> Vec<RunReport> {
    let (label, spec) = cfg.dna_tiers().into_iter().next().unwrap();
    let seqs = spec.generate();
    let mut out = Vec::new();
    for (tool, kernel) in [
        ("halign2_scalar", KernelBackend::Scalar),
        ("halign2_bitparallel", KernelBackend::BitParallel),
    ] {
        out.push(measure(tool, &label, "avgSP", || {
            let engine = Cluster::new(ClusterConfig::spark(cfg.workers));
            let msa = align_nucleotide(
                &engine,
                &seqs,
                &CenterStarConfig { kernel, ..Default::default() },
            )?;
            let sp = msa.avg_sp_distributed(&engine)?;
            Ok((msa, Some(sp), Some(engine)))
        }));
    }
    out
}

/// Figure 5 — average max per-worker memory: HAlign (Hadoop) vs SparkSW
/// vs HAlign-II on a DNA tier and a protein tier.
pub fn fig5_memory(cfg: &BenchConfig, svc: Option<&XlaService>) -> Vec<RunReport> {
    let mut out = Vec::new();
    let (dna_label, dna_spec) = cfg.dna_tiers().into_iter().nth(1).unwrap();
    let dna = dna_spec.generate();
    out.push(measure("halign_v1", &dna_label, "avgSP", || {
        let (msa, engine) =
            halign_v1::halign_v1_msa(cfg.workers, &dna, &CenterStarConfig::default())?;
        Ok((msa, None, Some(engine)))
    }));
    out.push(measure("halign2", &dna_label, "avgSP", || {
        let engine = Cluster::new(ClusterConfig::spark(cfg.workers));
        let msa = align_nucleotide(&engine, &dna, &CenterStarConfig::default())?;
        Ok((msa, None, Some(engine)))
    }));

    let (p_label, p_spec) = cfg.protein_tiers().into_iter().next().unwrap();
    let prot = p_spec.generate();
    out.push(measure("sparksw", &p_label, "avgSP", || {
        let (msa, engine) = sparksw::sparksw_msa(cfg.workers, &prot, 5.0)?;
        Ok((msa, None, Some(engine)))
    }));
    out.push(measure("halign2", &p_label, "avgSP", || {
        let engine = Cluster::new(ClusterConfig::spark(cfg.workers));
        let msa = align_protein(&engine, &prot, svc, &ProteinConfig::default())?;
        Ok((msa, None, Some(engine)))
    }));
    out
}

/// Figure 6 — runtime and memory vs worker count on a DNA tier, with the
/// work-stealing scheduler on ("halign2") and off ("halign2_nosteal") so
/// the busy-time skew column shows the load-balance win directly.
pub fn fig6_scaling(cfg: &BenchConfig) -> Vec<RunReport> {
    let (label, spec) = cfg.dna_tiers().into_iter().nth(1).unwrap();
    let seqs = spec.generate();
    let mut out = Vec::new();
    for workers in [1usize, 2, 4, 8, 12] {
        let name = format!("{label}@w{workers}");
        for (tool, steal) in [("halign2", true), ("halign2_nosteal", false)] {
            out.push(measure(tool, &name, "avgSP", || {
                let mut ccfg = ClusterConfig::spark(workers);
                ccfg.scheduler.work_stealing = steal;
                ccfg.scheduler.speculation = steal;
                let engine = Cluster::new(ccfg);
                let msa = align_nucleotide(&engine, &seqs, &CenterStarConfig::default())?;
                Ok((msa, None, Some(engine)))
            }));
        }
    }
    out
}

/// Figure 6 companion — scheduler-architecture A/B past the paper's 12
/// workstations: sharded per-worker deques with steal-half batching vs
/// the single global-mutex scheduler at 16/32/64 simulated workers.
/// Same MSA, identical results; the columns that differ are busy-time
/// skew, lock contention and wall-clock — the centralized-queue
/// bottleneck the sharding removes.
pub fn fig6_sharded(cfg: &BenchConfig) -> Vec<RunReport> {
    let (label, spec) = cfg.dna_tiers().into_iter().nth(1).unwrap();
    let seqs = spec.generate();
    let mut out = Vec::new();
    for workers in [16usize, 32, 64] {
        let name = format!("{label}@w{workers}");
        for (tool, mode) in [
            ("halign2_sharded", SchedulerMode::Sharded),
            ("halign2_global", SchedulerMode::GlobalLock),
        ] {
            out.push(measure(tool, &name, "avgSP", || {
                let mut ccfg = ClusterConfig::spark(workers);
                ccfg.scheduler.mode = mode;
                let engine = Cluster::new(ccfg);
                let msa = align_nucleotide(&engine, &seqs, &CenterStarConfig::default())?;
                let sp = msa.avg_sp_distributed(&engine)?;
                Ok((msa, Some(sp), Some(engine)))
            }));
        }
    }
    out
}

/// Figure 6 companion — a deliberately skewed workload (one in eight
/// sequences is ~5x longer), the straggler scenario the fixed modulo
/// placement handled worst: compare busy skew with stealing+speculation
/// on vs off.
pub fn fig6_skew(cfg: &BenchConfig) -> Vec<RunReport> {
    let ls = if cfg.quick { 0.02 } else { 0.1 };
    let short = DatasetSpec { count: cfg.count(147), ..DatasetSpec::mito(ls, cfg.seed ^ 5) };
    let long =
        DatasetSpec { count: cfg.count(147) / 7, ..DatasetSpec::mito(ls * 5.0, cfg.seed ^ 6) };
    let mut seqs = short.generate();
    seqs.extend(long.generate());
    let mut out = Vec::new();
    for (tool, steal) in [("halign2", true), ("halign2_nosteal", false)] {
        out.push(measure(tool, "dna_skewed", "avgSP", || {
            let mut ccfg = ClusterConfig::spark(cfg.workers);
            ccfg.scheduler.work_stealing = steal;
            ccfg.scheduler.speculation = steal;
            let engine = Cluster::new(ccfg);
            let msa = align_nucleotide(&engine, &seqs, &CenterStarConfig::default())?;
            let sp = msa.avg_sp_distributed(&engine)?;
            Ok((msa, Some(sp), Some(engine)))
        }));
    }
    out
}

/// Figure 6 companion — scheduler lifecycle traces: the fig6 MSA job run
/// with the obs trace rings enabled, followed by three deterministic
/// stages that force one steal batch, one speculative duplicate and one
/// kill-drain, so the exported Chrome trace JSON provably contains every
/// scheduler event kind — under BOTH queue architectures.  Returns
/// `(mode_label, chrome_trace_json)` pairs; the bench binary writes them
/// next to the TSV so CI archives a Perfetto-loadable artifact
/// (see rust/OBSERVABILITY.md).
pub fn fig6_trace(cfg: &BenchConfig) -> Vec<(&'static str, String)> {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::{Arc, Condvar, Mutex};

    use crate::obs::chrome_trace_json;

    let (_, spec) = cfg.dna_tiers().into_iter().next().unwrap();
    let seqs = spec.generate();
    let mut out = Vec::new();
    let mut telemetry: Vec<(&'static str, TraceTelemetry)> = Vec::new();
    for (label, mode) in
        [("sharded", SchedulerMode::Sharded), ("global", SchedulerMode::GlobalLock)]
    {
        let mut ccfg = ClusterConfig::spark(3);
        ccfg.scheduler.mode = mode;
        ccfg.scheduler.trace_capacity = 1 << 14;
        let engine = Cluster::new(ccfg);
        let t0 = Instant::now();
        align_nucleotide(&engine, &seqs, &CenterStarConfig::default())
            .expect("fig6 trace MSA");

        // Steal: task 0 blocks its owning worker until every peer task
        // has run, so the tasks queued behind it can only finish via
        // steal batches (same gate as the executor's stealing tests).
        let sync = Arc::new((Mutex::new(0usize), Condvar::new()));
        let s = sync.clone();
        engine
            .executor()
            .run_tasks(24, 0, move |task| {
                let (count, cv) = &*s;
                if task == 0 {
                    let done = count.lock().unwrap();
                    let (_, timeout) = cv
                        .wait_timeout_while(done, Duration::from_secs(20), |c| *c < 23)
                        .unwrap();
                    anyhow::ensure!(!timeout.timed_out(), "steal gate never opened");
                } else {
                    *count.lock().unwrap() += 1;
                    cv.notify_all();
                }
                Ok(())
            })
            .expect("steal stage");

        // Speculation: task 0's first attempt straggles until its
        // speculative duplicate has run, so the duplicate's completion
        // is what finishes the stage.
        let sync = Arc::new((Mutex::new(false), Condvar::new()));
        let execs = Arc::new(AtomicUsize::new(0));
        let (s, e) = (sync.clone(), execs.clone());
        engine
            .executor()
            .run_tasks(8, 0, move |task| {
                if task != 0 {
                    return Ok(());
                }
                let (dup_ran, cv) = &*s;
                if e.fetch_add(1, Ordering::SeqCst) == 0 {
                    let flag = dup_ran.lock().unwrap();
                    let (_, timeout) = cv
                        .wait_timeout_while(flag, Duration::from_secs(20), |ran| !*ran)
                        .unwrap();
                    anyhow::ensure!(
                        !timeout.timed_out(),
                        "no speculative duplicate was launched"
                    );
                } else {
                    *dup_ran.lock().unwrap() = true;
                    cv.notify_all();
                }
                Ok(())
            })
            .expect("speculation stage");

        // Kill-drain: retire a worker; the drain event lands on the
        // driver lane even when the deque is already empty.
        assert!(engine.executor().kill_worker(0), "kill must succeed");

        let events = engine.trace().drain_new();
        let count = |kind: TraceKind| events.iter().filter(|e| e.kind == kind).count() as u64;
        let profile = Profile::from_events(&events, engine.trace().num_lanes());
        telemetry.push((
            label,
            TraceTelemetry {
                tasks: count(TraceKind::Finish),
                steals: count(TraceKind::Steal),
                speculative_launches: count(TraceKind::SpeculativeLaunch),
                kill_drained: count(TraceKind::KillDrain),
                critical_path_frac: profile.critical_path_frac,
                wall_secs: t0.elapsed().as_secs_f64(),
            },
        ));
        out.push((label, chrome_trace_json(&events, engine.trace().num_lanes())));
    }

    // Machine-readable section: both queue architectures must show the
    // forced steal / speculation / kill-drain episodes, and the critical
    // path must stay a strict fraction of the wall-clock (the
    // speculation stage's deadline wait is wall with no path on it).
    if let (Some(s), Some(g)) = (
        telemetry.iter().find(|(l, _)| *l == "sharded").map(|(_, t)| t),
        telemetry.iter().find(|(l, _)| *l == "global").map(|(_, t)| t),
    ) {
        write_bench_json(
            "fig6",
            &[
                ("sharded_tasks_run", s.tasks.to_string()),
                ("sharded_steals", s.steals.to_string()),
                ("sharded_speculative_launches", s.speculative_launches.to_string()),
                ("sharded_kill_drained", s.kill_drained.to_string()),
                ("sharded_critical_path_frac", format!("{:.6}", s.critical_path_frac)),
                ("sharded_wall_secs", format!("{:.6}", s.wall_secs)),
                ("global_tasks_run", g.tasks.to_string()),
                ("global_steals", g.steals.to_string()),
                ("global_speculative_launches", g.speculative_launches.to_string()),
                ("global_kill_drained", g.kill_drained.to_string()),
                ("global_critical_path_frac", format!("{:.6}", g.critical_path_frac)),
                ("global_wall_secs", format!("{:.6}", g.wall_secs)),
            ],
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> BenchConfig {
        BenchConfig { quick: true, workers: 2, budget: Duration::from_secs(10), ..Default::default() }
    }

    /// The fresh scenario section the scenario run just wrote, read back
    /// from the repo root.
    fn bench_json(scenario: &str) -> String {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .unwrap()
            .join(format!("BENCH_{scenario}.json"));
        std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()))
    }

    #[test]
    fn table2_has_all_tools_and_halign2_wins_busy_time() {
        let rows = table2_genome(&quick());
        assert!(rows.iter().any(|r| r.tool == "halign2" && r.dnf.is_none()));
        assert!(rows.iter().any(|r| r.tool == "halign_v1"));
        assert!(rows.iter().any(|r| r.tool == "progressive"));
        // HAlign v1 and HAlign-II report the same avg SP (same algorithm).
        for d in ["dna_1x"] {
            let v1 = rows.iter().find(|r| r.tool == "halign_v1" && r.dataset == d).unwrap();
            let v2 = rows.iter().find(|r| r.tool == "halign2" && r.dataset == d).unwrap();
            assert_eq!(v1.metric, v2.metric, "same center-star, same SP");
        }
        // Machine-readable section written next to the baselines.
        let json = bench_json("table2");
        assert!(crate::obs::is_json_object(&json), "{json}");
        assert!(json.contains("\"sp_match\": true"), "{json}");
        for key in ["tasks_run", "critical_path_frac", "steals"] {
            assert!(json.contains(key), "BENCH_table2.json missing {key}: {json}");
        }
    }

    #[test]
    fn table5_smoke_runs_dense_and_tiled_with_peak_column() {
        // Smoke mode for the CI bench job: tiny n, both distance
        // backends.  Guards against panics, a missing
        // peak-resident-bytes column, and dense/tiled divergence.
        let rows = table5_tree(&quick(), None);
        let tiled: Vec<_> = rows.iter().filter(|r| r.tool == "halign2_tiled").collect();
        let dense: Vec<_> = rows.iter().filter(|r| r.tool == "halign2_dense").collect();
        assert_eq!(tiled.len(), 3, "tiled rows at 16/32/64 workers");
        assert_eq!(dense.len(), 3, "dense rows at 16/32/64 workers");
        for w in ["16", "32", "64"] {
            let suffix = format!("@w{w}");
            let t: &RunReport =
                tiled.iter().find(|r| r.dataset.ends_with(&suffix)).unwrap();
            let d: &RunReport =
                dense.iter().find(|r| r.dataset.ends_with(&suffix)).unwrap();
            assert!(t.dnf.is_none() && d.dnf.is_none(), "w{w}: no DNFs");
            assert_eq!(t.metric, d.metric, "w{w}: backends must agree on logML exactly");
            let (tp, dp) = (t.distmat_peak_mb.unwrap(), d.distmat_peak_mb.unwrap());
            assert!(tp > 0.0 && dp > 0.0, "w{w}: peak column must be populated");
            assert!(tp <= dp, "w{w}: tiled peak ({tp}) must not exceed dense ({dp})");
            // The TSV rendering the CI job greps for.
            let line = crate::metrics::tsv_line(t);
            assert_eq!(
                line.split('\t').count(),
                crate::metrics::TSV_HEADER.split('\t').count(),
                "row arity matches the header (which carries distmat_peak_mb)"
            );
            assert!(!line.split('\t').nth(11).unwrap().contains('-'), "peak cell is numeric");
        }
        // Machine-readable section: the tiled/dense peak ratio and the
        // critical-path fraction the bench gate caps.
        let json = bench_json("table5");
        assert!(crate::obs::is_json_object(&json), "{json}");
        assert!(json.contains("\"backends_agree\": true"), "{json}");
        for key in [
            "distmat_peak_bytes_dense",
            "distmat_peak_bytes_tiled",
            "peak_bytes_ratio",
            "critical_path_frac",
        ] {
            assert!(json.contains(key), "BENCH_table5.json missing {key}: {json}");
        }
    }

    #[test]
    fn kernel_ab_backends_agree_exactly() {
        let rows = kernel_ab(&quick());
        assert_eq!(rows.len(), 2, "scalar and bitparallel rows");
        assert!(rows.iter().all(|r| r.dnf.is_none()));
        assert!(rows.iter().any(|r| r.tool == "halign2_scalar"));
        assert!(rows.iter().any(|r| r.tool == "halign2_bitparallel"));
        assert_eq!(
            rows[0].metric, rows[1].metric,
            "kernel backend must not change the MSA"
        );
    }

    #[test]
    fn fig6_covers_both_schedulers_per_worker_count() {
        let rows = fig6_scaling(&quick());
        assert_eq!(rows.len(), 10, "5 worker counts x steal on/off");
        assert!(rows.iter().all(|r| r.dnf.is_none()));
        assert!(rows.iter().any(|r| r.tool == "halign2_nosteal"));
        assert!(rows.iter().all(|r| r.busy_skew.is_some()));
        // Identical results regardless of scheduler.
        for w in ["1", "2"] {
            let name = format!("dna_20x@w{w}");
            let pair: Vec<_> = rows.iter().filter(|r| r.dataset == name).collect();
            assert_eq!(pair.len(), 2);
        }
    }

    #[test]
    fn fig6_sharded_covers_both_architectures_with_identical_results() {
        let rows = fig6_sharded(&quick());
        assert_eq!(rows.len(), 6, "3 worker counts x sharded/global");
        assert!(rows.iter().all(|r| r.dnf.is_none()));
        for w in ["16", "32", "64"] {
            let name = format!("dna_20x@w{w}");
            let pair: Vec<_> = rows.iter().filter(|r| r.dataset == name).collect();
            assert_eq!(pair.len(), 2);
            assert_eq!(
                pair[0].metric, pair[1].metric,
                "queue architecture must not change the MSA"
            );
        }
        assert!(rows.iter().all(|r| r.busy_skew.is_some() && r.lock_contentions.is_some()));
    }

    #[test]
    fn fig6_trace_exports_every_scheduler_event_in_both_modes() {
        // ISSUE-9 acceptance: a fig6 job's exported trace is a valid
        // Chrome trace-event array containing steal, speculation and
        // kill-drain events, from both queue architectures.
        let traces = fig6_trace(&quick());
        assert_eq!(traces.len(), 2, "sharded and global traces");
        assert!(traces.iter().any(|(l, _)| *l == "sharded"));
        assert!(traces.iter().any(|(l, _)| *l == "global"));
        for (label, json) in &traces {
            assert!(
                crate::obs::is_json_array(json),
                "{label}: export must be a valid JSON array"
            );
            for needle in [
                "\"steal\"",
                "\"speculative_launch\"",
                "\"kill_drain\"",
                "\"task\"",
                "\"enqueue\"",
                "\"driver\"",
            ] {
                assert!(json.contains(needle), "{label}: trace must contain {needle}");
            }
        }
        // Machine-readable section: both modes' counters and fractions.
        let json = bench_json("fig6");
        assert!(crate::obs::is_json_object(&json), "{json}");
        for key in [
            "sharded_steals",
            "sharded_speculative_launches",
            "sharded_kill_drained",
            "sharded_critical_path_frac",
            "global_steals",
            "global_critical_path_frac",
        ] {
            assert!(json.contains(key), "BENCH_fig6.json missing {key}: {json}");
        }
    }

    #[test]
    fn fig6_skew_compares_schedulers_on_skewed_data() {
        let rows = fig6_skew(&quick());
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().all(|r| r.dnf.is_none()));
        // Same deterministic MSA: the SP metric must agree exactly.
        assert_eq!(rows[0].metric, rows[1].metric, "scheduler must not change results");
        assert!(rows.iter().all(|r| r.busy_skew.unwrap() >= 1.0));
    }
}

"""Kernel-vs-reference correctness: the build-time gate for the artifacts.

Every Pallas kernel is compared against the pure numpy/jnp oracles in
compile.kernels.ref — exact equality where scores are integer-valued,
allclose elsewhere.  Hypothesis sweeps shapes and alphabets.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile import model
from compile.kernels import distance, ref, sw

RNG = np.random.default_rng(7)


def blosum_like(alpha, rng):
    """Random symmetric integer substitution matrix with a sentinel row."""
    m = rng.integers(-4, 12, size=(alpha, alpha)).astype(np.float32)
    m = np.tril(m) + np.tril(m, -1).T
    m[alpha - 1, :] = -1e4  # padding sentinel never matches
    m[:, alpha - 1] = -1e4
    return m


# ---------------------------------------------------------------------------
# Smith-Waterman wavefront kernel
# ---------------------------------------------------------------------------


class TestSwKernel:
    def run_case(self, batch, m, n, alpha, seed):
        rng = np.random.default_rng(seed)
        subst = blosum_like(alpha, rng)
        gap = np.float32(3.0)
        a = rng.integers(0, alpha - 1, size=(batch, m)).astype(np.int32)
        b = rng.integers(0, alpha - 1, size=(n,)).astype(np.int32)
        hd = np.asarray(
            sw.sw_batch(
                jnp.asarray(a), jnp.asarray(b), jnp.asarray(subst), jnp.asarray([gap])
            )
        )
        assert hd.shape == (batch, m + n + 1, m + 1)
        for k in range(batch):
            h_ref = ref.sw_matrix_ref(a[k], b, subst, gap)
            np.testing.assert_array_equal(
                ref.row_major(hd[k], m, n), h_ref, err_msg=f"batch element {k}"
            )

    def test_small_exact(self):
        self.run_case(batch=3, m=7, n=9, alpha=5, seed=1)

    def test_square(self):
        self.run_case(batch=2, m=12, n=12, alpha=25, seed=2)

    def test_query_longer_than_center(self):
        self.run_case(batch=2, m=15, n=6, alpha=8, seed=3)

    def test_center_longer_than_query(self):
        self.run_case(batch=2, m=6, n=15, alpha=8, seed=4)

    def test_single_element_batch(self):
        self.run_case(batch=1, m=10, n=10, alpha=25, seed=5)

    def test_minimal_lengths(self):
        self.run_case(batch=2, m=1, n=1, alpha=4, seed=6)

    def test_identical_sequences_peak_on_diagonal(self):
        alpha = 5
        subst = np.full((alpha, alpha), -2.0, np.float32)
        np.fill_diagonal(subst, 5.0)
        a = np.array([[0, 1, 2, 3, 0, 1]], np.int32)
        hd = np.asarray(
            sw.sw_batch(
                jnp.asarray(a),
                jnp.asarray(a[0]),
                jnp.asarray(subst),
                jnp.asarray([4.0], np.float32),
            )
        )
        h = ref.row_major(hd[0], 6, 6)
        assert h[6, 6] == 30.0  # perfect self-alignment: 6 matches * 5

    def test_padding_sentinel_never_extends(self):
        """Sentinel-padded tails must not raise any H cell above the
        unpadded optimum (the batcher relies on this)."""
        alpha = 7
        rng = np.random.default_rng(8)
        subst = blosum_like(alpha, rng)
        gap = np.float32(2.0)
        a_real = rng.integers(0, alpha - 1, size=(1, 8)).astype(np.int32)
        b = rng.integers(0, alpha - 1, size=(10,)).astype(np.int32)
        a_pad = np.concatenate(
            [a_real, np.full((1, 4), alpha - 1, np.int32)], axis=1
        )
        hd_real = np.asarray(
            sw.sw_batch(jnp.asarray(a_real), jnp.asarray(b), jnp.asarray(subst),
                        jnp.asarray([gap]))
        )
        hd_pad = np.asarray(
            sw.sw_batch(jnp.asarray(a_pad), jnp.asarray(b), jnp.asarray(subst),
                        jnp.asarray([gap]))
        )
        assert hd_pad.max() == hd_real.max()

    @settings(max_examples=25, deadline=None)
    @given(
        batch=st.integers(1, 3),
        m=st.integers(1, 16),
        n=st.integers(1, 16),
        alpha=st.integers(3, 25),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_sweep(self, batch, m, n, alpha, seed):
        self.run_case(batch, m, n, alpha, seed)

    def test_matches_jnp_score_reference(self):
        rng = np.random.default_rng(11)
        alpha = 25
        subst = blosum_like(alpha, rng)
        gap = np.float32(3.0)
        a = rng.integers(0, alpha - 1, size=(4, 20)).astype(np.int32)
        b = rng.integers(0, alpha - 1, size=(24,)).astype(np.int32)
        hd = np.asarray(
            sw.sw_batch(jnp.asarray(a), jnp.asarray(b), jnp.asarray(subst),
                        jnp.asarray([gap]))
        )
        best_kernel = hd.max(axis=(1, 2))
        best_ref = np.asarray(
            ref.jnp_sw_scores(
                jnp.asarray(a, jnp.int32),
                jnp.asarray(b, jnp.int32),
                jnp.asarray(subst),
                jnp.asarray(gap),
            )
        )
        np.testing.assert_allclose(best_kernel, best_ref, rtol=0, atol=0)


# ---------------------------------------------------------------------------
# Gram / distance kernels
# ---------------------------------------------------------------------------


class TestGramKernel:
    def test_exact_integer_grams(self):
        x = RNG.integers(0, 9, size=(128, 256)).astype(np.float32)
        g = np.asarray(distance.gram_matrix(jnp.asarray(x)))
        np.testing.assert_array_equal(g, ref.gram_ref(x))

    def test_float_allclose(self):
        x = RNG.normal(size=(128, 256)).astype(np.float32)
        g = np.asarray(distance.gram_matrix(jnp.asarray(x)))
        np.testing.assert_allclose(g, ref.gram_ref(x), rtol=1e-5, atol=1e-4)

    def test_single_tile(self):
        x = RNG.normal(size=(64, 128)).astype(np.float32)
        g = np.asarray(distance.gram_matrix(jnp.asarray(x)))
        np.testing.assert_allclose(g, ref.gram_ref(x), rtol=1e-5, atol=1e-4)

    def test_multi_k_accumulation(self):
        """D = 4 tiles of 128: exercises the k-loop accumulator reuse."""
        x = RNG.normal(size=(64, 512)).astype(np.float32)
        g = np.asarray(distance.gram_matrix(jnp.asarray(x)))
        np.testing.assert_allclose(g, ref.gram_ref(x), rtol=1e-5, atol=1e-3)

    def test_sqdist(self):
        x = RNG.integers(0, 5, size=(128, 256)).astype(np.float32)
        d2 = np.asarray(distance.kmer_sqdist(jnp.asarray(x)))
        np.testing.assert_allclose(d2, ref.sqdist_ref(x), rtol=1e-5, atol=1e-3)
        assert (np.diagonal(d2) == 0).all()
        np.testing.assert_allclose(d2, d2.T, atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(
        nt=st.integers(1, 3),
        kt=st.integers(1, 4),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_tile_counts(self, nt, kt, seed):
        rng = np.random.default_rng(seed)
        x = rng.normal(size=(64 * nt, 128 * kt)).astype(np.float32)
        g = np.asarray(distance.gram_matrix(jnp.asarray(x)))
        np.testing.assert_allclose(g, ref.gram_ref(x), rtol=1e-5, atol=1e-3)


class TestMatchCounts:
    def test_dna_exact(self):
        codes = RNG.integers(0, model.DNA_ALPHA, size=(64, 100)).astype(np.int32)
        mc = np.asarray(model.match_counts_dna(jnp.asarray(codes)))
        np.testing.assert_array_equal(mc, ref.match_counts_ref(codes))

    def test_protein_exact(self):
        codes = RNG.integers(0, model.PROTEIN_ALPHA, size=(64, 64)).astype(np.int32)
        mc = np.asarray(model.match_counts_protein(jnp.asarray(codes)))
        np.testing.assert_array_equal(mc, ref.match_counts_ref(codes))

    def test_identical_rows_full_count(self):
        row = RNG.integers(0, 6, size=(1, 96)).astype(np.int32)
        codes = np.repeat(row, 64, axis=0)
        mc = np.asarray(model.match_counts_dna(jnp.asarray(codes)))
        np.testing.assert_array_equal(mc, np.full((64, 64), 96.0, np.float32))

    def test_padding_is_constant_offset(self):
        """pad_cols_to with a shared fill adds exactly (width-L) matches."""
        codes = RNG.integers(0, 5, size=(64, 50)).astype(np.int32)
        base = np.asarray(model.match_counts_dna(jnp.asarray(codes)))
        padded = model.pad_cols_to(jnp.asarray(codes), 96, model.DNA_ALPHA - 1)
        mc = np.asarray(model.match_counts_dna(padded))
        np.testing.assert_array_equal(mc, base + 46.0)


# ---------------------------------------------------------------------------
# Model-level shape contracts (what aot.py bakes into the artifacts)
# ---------------------------------------------------------------------------


class TestModelShapes:
    def test_sw_align_shape(self):
        b, m, n, alpha = 2, 16, 24, model.PROTEIN_ALPHA
        out = model.sw_align(
            jnp.zeros((b, m), jnp.int32),
            jnp.zeros((n,), jnp.int32),
            jnp.zeros((alpha, alpha), jnp.float32),
            jnp.asarray([2.0], jnp.float32),
        )
        assert out.shape == (b, m + n + 1, m + 1)

    def test_kmer_sqdist_shape(self):
        out = model.kmer_sqdist(jnp.zeros((64, 256), jnp.float32))
        assert out.shape == (64, 64)

    def test_lowering_smoke(self):
        """The exact lowering path aot.py uses must produce parseable HLO
        text with the expected entry computation."""
        from compile import aot

        text = aot.lower_one(
            lambda x: (model.kmer_sqdist(x),),
            (jax.ShapeDtypeStruct((64, 128), jnp.float32),),
        )
        assert "ENTRY" in text and "f32[64,64]" in text

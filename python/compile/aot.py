"""AOT compiler: lower every (L2 program, shape bucket) pair to HLO text.

This is the ONLY python entry point in the build; `make artifacts` runs it
once and the Rust coordinator is self-contained afterwards.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts land in artifacts/ together with `manifest.txt`, one line per
executable:

    name<TAB>file<TAB>kind<TAB>shape-params (k=v, comma separated)

which rust/src/runtime/artifacts.rs parses to build its registry.  Shape
buckets are the contract between the Rust batcher (which pads requests up
to a bucket) and the fixed-shape PJRT executables.

Usage: python -m compile.aot --out-dir ../artifacts [--quick]
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# ---------------------------------------------------------------------------
# Shape buckets (the Rust batcher must agree — rust/src/runtime/artifacts.rs)
# ---------------------------------------------------------------------------

# (batch, query_len, center_len): protein SW.  Avg BAliBASE R10 length is
# 459 aa, so the 512 bucket covers the bulk; 128 catches short sequences
# cheaply; overlong sequences fall back to the Rust SW path.
SW_BUCKETS = [
    (8, 128, 128),
    (8, 512, 512),
]
SW_BUCKETS_QUICK = [(4, 32, 32)]

# (n_rows, dim): k-mer profile distance (k=4 -> D=256).
GRAM_BUCKETS = [(128, 256)]
GRAM_BUCKETS_QUICK = [(64, 128)]

# (n_rows, aligned_len): NJ match counts.  DNA alignment columns for the
# mito dataset pad to 1024 after the quick-path; rRNA to 2048.
MATCH_DNA_BUCKETS = [(128, 2048)]
MATCH_PROTEIN_BUCKETS = [(128, 640)]
MATCH_DNA_BUCKETS_QUICK = [(64, 128)]
MATCH_PROTEIN_BUCKETS_QUICK = [(64, 64)]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side unwraps with to_tuple1)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(fn, specs):
    return to_hlo_text(jax.jit(fn).lower(*specs))


def emit(out_dir, manifest, name, kind, params, fn, specs):
    text = lower_one(fn, specs)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    pstr = ",".join(f"{k}={v}" for k, v in params)
    manifest.append(f"{name}\t{fname}\t{kind}\t{pstr}")
    print(f"  {name}: {len(text)} chars", file=sys.stderr)


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--quick",
        action="store_true",
        help="also emit tiny buckets used by the Rust integration tests",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = []
    alpha = model.PROTEIN_ALPHA

    sw_buckets = SW_BUCKETS + (SW_BUCKETS_QUICK if args.quick else [])
    for b, m, n in sw_buckets:
        emit(
            args.out_dir,
            manifest,
            f"sw_b{b}_q{m}_c{n}",
            "sw",
            [("b", b), ("m", m), ("n", n), ("alpha", alpha)],
            lambda a, c, s, g: (model.sw_align(a, c, s, g),),
            (i32(b, m), i32(n), f32(alpha, alpha), f32(1)),
        )

    gram_buckets = GRAM_BUCKETS + (GRAM_BUCKETS_QUICK if args.quick else [])
    for n, d in gram_buckets:
        emit(
            args.out_dir,
            manifest,
            f"kmerdist_n{n}_d{d}",
            "kmerdist",
            [("n", n), ("d", d)],
            lambda x: (model.kmer_sqdist(x),),
            (f32(n, d),),
        )

    dna_buckets = MATCH_DNA_BUCKETS + (
        MATCH_DNA_BUCKETS_QUICK if args.quick else []
    )
    for n, l in dna_buckets:
        emit(
            args.out_dir,
            manifest,
            f"matchdna_n{n}_l{l}",
            "match_dna",
            [("n", n), ("l", l), ("alpha", model.DNA_ALPHA)],
            lambda c: (model.match_counts_dna(c),),
            (i32(n, l),),
        )

    prot_buckets = MATCH_PROTEIN_BUCKETS + (
        MATCH_PROTEIN_BUCKETS_QUICK if args.quick else []
    )
    for n, l in prot_buckets:
        emit(
            args.out_dir,
            manifest,
            f"matchprot_n{n}_l{l}",
            "match_protein",
            [("n", n), ("l", l), ("alpha", alpha)],
            lambda c: (model.match_counts_protein(c),),
            (i32(n, l),),
        )

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {len(manifest)} artifacts to {args.out_dir}", file=sys.stderr)


if __name__ == "__main__":
    main()

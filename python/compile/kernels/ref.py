"""Pure-jnp / pure-python oracles for the Pallas kernels.

These are the CORE correctness signal: python/tests compares every kernel
against these references (exact for integer-valued scores, allclose for
float paths), and the Rust side's unit tests embed small cases whose
expected values were derived from the same recurrences.
"""

import numpy as np
import jax.numpy as jnp


def sw_matrix_ref(a, b, subst, gap):
    """Textbook O(m*n) Smith-Waterman H matrix (numpy, row-major).

    a: (m,) int codes, b: (n,) int codes, subst: (alpha, alpha), gap: float.
    Returns H of shape (m+1, n+1), H[0,:] = H[:,0] = 0.
    """
    m, n = len(a), len(b)
    h = np.zeros((m + 1, n + 1), dtype=np.float64)
    for i in range(1, m + 1):
        for j in range(1, n + 1):
            s = subst[a[i - 1], b[j - 1]]
            h[i, j] = max(
                0.0,
                h[i - 1, j - 1] + s,
                h[i - 1, j] - gap,
                h[i, j - 1] - gap,
            )
    return h.astype(np.float32)


def diag_major(h):
    """Convert a row-major (m+1, n+1) H into the kernel's diagonal-major
    layout hd[d, i] = H[i, d-i] (zeros outside the band)."""
    m1, n1 = h.shape
    m, n = m1 - 1, n1 - 1
    hd = np.zeros((m + n + 1, m + 1), dtype=np.float32)
    for i in range(m + 1):
        for j in range(n + 1):
            hd[i + j, i] = h[i, j]
    return hd


def row_major(hd, m, n):
    """Inverse of diag_major (mirrors the Rust re-indexing)."""
    h = np.zeros((m + 1, n + 1), dtype=np.float32)
    for i in range(m + 1):
        for j in range(n + 1):
            h[i, j] = hd[i + j, i]
    return h


def gram_ref(x):
    """G = x @ x^T in f64 then cast, the tightest reference for tiling."""
    x = np.asarray(x, dtype=np.float64)
    return (x @ x.T).astype(np.float32)


def sqdist_ref(x):
    g = gram_ref(x).astype(np.float64)
    d = np.diagonal(g)
    return np.maximum(d[:, None] + d[None, :] - 2.0 * g, 0.0).astype(np.float32)


def match_counts_ref(codes):
    """Pairwise equal-column counts, O(n^2 * l) python loop."""
    codes = np.asarray(codes)
    n = codes.shape[0]
    out = np.zeros((n, n), dtype=np.float32)
    for i in range(n):
        for j in range(n):
            out[i, j] = float(np.sum(codes[i] == codes[j]))
    return out


def jnp_sw_scores(a_batch, b, subst, gap):
    """Vectorized-over-batch jnp reference for final best scores only
    (used by perf comparisons: scan over query rows, scan along columns)."""
    import jax

    def one(a):
        def row_step(prev_row, ai):
            s_row = subst[ai, b]  # (n,)

            def col_step(left, inputs):
                up, diag, s = inputs
                val = jnp.maximum(
                    0.0, jnp.maximum(diag + s, jnp.maximum(up, left) - gap)
                )
                return val, val

            diag_vals = jnp.concatenate([jnp.zeros((1,)), prev_row[:-1]])
            _, row = jax.lax.scan(col_step, 0.0, (prev_row, diag_vals, s_row))
            return row, jnp.max(row)

        init = jnp.zeros((b.shape[0],))
        _, maxes = jax.lax.scan(row_step, init, a)
        return jnp.max(maxes)

    return jax.vmap(one)(a_batch)

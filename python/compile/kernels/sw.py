"""L1 Pallas kernel: batched Smith-Waterman scoring via anti-diagonal wavefront.

HAlign-II uses Smith-Waterman (linear gap penalty, substitution matrix) for
protein pairwise alignment against the broadcast center-star sequence.  The
DP recurrence

    H[i,j] = max(0,
                 H[i-1,j-1] + s(a_i, b_j),
                 H[i-1,j]   - gap,
                 H[i,j-1]   - gap)

has a row-wise *and* column-wise dependency, so neither rows nor columns
vectorize.  Every cell on an anti-diagonal d = i+j, however, depends only on
diagonals d-1 and d-2 — the classical wavefront formulation.  We therefore
iterate over the m+n diagonals and compute each diagonal as one vector op
over its lanes.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the three live diagonals
are (m+1)-lane f32 vectors that sit comfortably in VMEM (3 * 513 * 4B ≈ 6 KB
for the 512-bucket); the H output is written diagonal-major so each step is
a contiguous row store.  The substitution lookup s(a_i, b_{d-i}) is a
vectorized gather from a small (A*A,) table resident in VMEM.

Output layout: ``hd[b, d, i] = H[i, d-i]`` for the b-th query — i.e. H in
diagonal-major order, including the zero boundary row/column.  The Rust side
(rust/src/align/protein.rs) re-indexes ``H[i][j] = hd[i+j][i]`` and runs the
O(m+n) traceback from the argmax, re-deriving the predecessor choice from H
itself (no pointer matrix needed).

The kernel MUST be lowered with interpret=True: real TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def sw_wavefront_kernel(a_ref, b_ref, subst_ref, gap_ref, hd_ref, *, m, n, alpha):
    """One batch element: query a (m,) int32 vs center b (n,) int32.

    a_ref:     (m,)   int32  query codes, padded with `alpha - 1` (sentinel)
    b_ref:     (n,)   int32  center codes (sentinel-padded likewise)
    subst_ref: (alpha*alpha,) f32 flattened substitution matrix; the
               sentinel row/column must hold a large negative score so that
               padding never extends an alignment.
    gap_ref:   (1,)   f32    linear gap penalty (positive value, subtracted)
    hd_ref:    (m+n+1, m+1) f32 out, diagonal-major H (see module docstring)
    """
    a = a_ref[...]
    b = b_ref[...]
    subst = subst_ref[...]
    gap = gap_ref[0]

    lanes = m + 1  # lane l corresponds to row index i = l
    iota = jax.lax.iota(jnp.int32, lanes)

    # a_lane[l] = code of a_{i=l} (1-based row i uses a[i-1]); lane 0 unused.
    a_lane = jnp.where(iota >= 1, a[jnp.clip(iota - 1, 0, m - 1)], alpha - 1)

    zeros = jnp.zeros((lanes,), jnp.float32)
    hd_ref[0, :] = zeros
    hd_ref[1, :] = zeros

    def step(d, carry):
        # carry: (H on diagonal d-1, H on diagonal d-2), lane-indexed by i.
        hm1, hm2 = carry
        j = d - iota  # column index per lane
        valid = (iota >= 1) & (iota <= m) & (j >= 1) & (j <= n)
        # substitution score s(a_i, b_j) per lane (clip keeps gathers in
        # bounds; `valid` masks the result).
        b_lane = b[jnp.clip(j - 1, 0, n - 1)]
        s = subst[a_lane * alpha + b_lane]
        # diag move uses H[i-1, j-1] = hm2[i-1]; up uses H[i-1, j] = hm1[i-1]
        hm2_shift = jnp.roll(hm2, 1).at[0].set(0.0)
        hm1_shift = jnp.roll(hm1, 1).at[0].set(0.0)
        h = jnp.maximum(
            jnp.maximum(hm2_shift + s, hm1_shift - gap),
            jnp.maximum(hm1 - gap, 0.0),
        )
        h = jnp.where(valid, h, 0.0)
        hd_ref[d, :] = h
        return (h, hm1)

    jax.lax.fori_loop(2, m + n + 1, step, (zeros, zeros))


def sw_batch(a_codes, b_codes, subst, gap, *, interpret=True):
    """Batched SW wavefront: vmap of the single-pair Pallas kernel.

    a_codes: (B, m) int32; b_codes: (n,) int32; subst: (alpha, alpha) f32;
    gap: (1,) f32.  Returns hd: (B, m+n+1, m+1) f32.
    """
    batch, m = a_codes.shape
    (n,) = b_codes.shape
    alpha = subst.shape[0]
    kern = functools.partial(sw_wavefront_kernel, m=m, n=n, alpha=alpha)
    call = pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((m + n + 1, m + 1), jnp.float32),
        interpret=interpret,
    )
    flat_subst = subst.reshape(-1)
    return jax.vmap(lambda a: call(a, b_codes, flat_subst, gap))(a_codes)

"""L1 Pallas kernel: tiled Gram / squared-distance matrix.

HAlign-II's phylogeny stage needs all-pairs distances twice:

  * k-mer profile distances for the initial ~10% sampling clustering
    (rows = k-mer count vectors, D = 4^k), and
  * match-count / p-distances over aligned sequences for neighbor-joining
    (rows = one-hot encoded alignment columns, D = L * alphabet, where a
    dot product counts exactly the matching columns).

Both reduce to  G = X @ X^T,  from which
  sqdist(i,j) = g_ii + g_jj - 2 g_ij      (k-mer profiles)
  matches(i,j) = g_ij                     (one-hot rows)

so a single tiled matmul kernel serves both.  This is the MXU-shaped kernel
of the reproduction: tiles of X stream HBM->VMEM via BlockSpec, each grid
step contracts a (tm, td) x (td, tn) block pair on the systolic array, and
the (tm, tn) f32 accumulator lives in the output VMEM block across the
contraction loop.

interpret=True for CPU-PJRT execution (see sw.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def gram_tile_kernel(x_ref, y_ref, o_ref):
    """Accumulate one contraction step: o += x_tile @ y_tile^T.

    Grid = (M/tm, N/tn, D/td); the k-th grid axis walks the contraction.
    x_ref: (tm, td), y_ref: (tn, td), o_ref: (tm, tn) accumulator.
    """
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...].T, preferred_element_type=jnp.float32
    )


def gram_matrix(x, *, tm=64, tn=64, td=128, interpret=True):
    """G = x @ x^T via the tiled Pallas kernel. x: (N, D) f32 -> (N, N) f32.

    N must be divisible by tm and tn, D by td (aot.py only emits such
    buckets; the Rust batcher pads rows with zeros, which contribute nothing
    to the Gram matrix).
    """
    n, d = x.shape
    assert n % tm == 0 and n % tn == 0 and d % td == 0, (n, d, tm, tn, td)
    grid = (n // tm, n // tn, d // td)
    return pl.pallas_call(
        gram_tile_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, td), lambda i, j, k: (i, k)),
            pl.BlockSpec((tn, td), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=interpret,
    )(x, x)


def sqdist_from_gram(g):
    """sqdist(i,j) = g_ii + g_jj - 2 g_ij, clamped at 0 for fp round-off."""
    diag = jnp.diagonal(g)
    return jnp.maximum(diag[:, None] + diag[None, :] - 2.0 * g, 0.0)


def kmer_sqdist(x, *, interpret=True, **tiles):
    """Squared euclidean distance between k-mer profile rows of x."""
    return sqdist_from_gram(gram_matrix(x, interpret=interpret, **tiles))


def match_counts(codes, alpha, *, interpret=True, **tiles):
    """Pairwise matching-column counts between aligned integer sequences.

    codes: (N, L) int32 in [0, alpha); gaps/sentinels must already be mapped
    to a dedicated code — matching gaps count as matches here and are
    corrected by the caller (rust/src/tree/distance.rs keeps per-pair gap
    tallies).  One-hot to (N, L*alpha) then a Gram matmul counts matches:
    dot(onehot_i, onehot_j) = #columns where codes agree.
    """
    n, l = codes.shape
    onehot = jax.nn.one_hot(codes, alpha, dtype=jnp.float32).reshape(n, l * alpha)
    # Zero-pad the contraction dim to the tile width; zero columns add
    # nothing to the Gram matrix, so this is exact.
    td = tiles.get("td", 128)
    d = onehot.shape[1]
    pad = (-d) % td
    if pad:
        onehot = jnp.pad(onehot, ((0, 0), (0, pad)))
    return gram_matrix(onehot, interpret=interpret, **tiles)

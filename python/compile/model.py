"""L2: the jax compute graphs HAlign-II ships to the Rust coordinator.

Each function here is a *whole lowered program*: it composes the L1 Pallas
kernels with the surrounding jnp glue (masking, one-hot, distance algebra)
so a single PJRT executable serves one coordinator request.  aot.py lowers
every (function, shape-bucket) pair once to HLO text; python never runs at
request time.

Programs
--------
sw_align     : (a_codes (B,m) i32, b_codes (n,) i32, subst (A,A) f32,
                gap (1,) f32) -> hd (B, m+n+1, m+1) f32
               Batched Smith-Waterman H matrices (diagonal-major) of B
               padded queries against the broadcast center sequence; the
               Rust side does traceback.  Hot path of protein center-star.

kmer_sqdist  : (x (N,D) f32) -> (N,N) f32
               Squared-euclidean distances between k-mer profiles; used by
               the ~10% sampling clustering before NJ.

match_counts : (codes (N,L) i32) -> (N,N) f32
               Pairwise matching-column counts over aligned sequences
               (one-hot + Gram matmul); the NJ p-distance numerator.
"""

import jax.numpy as jnp

from compile.kernels import distance, sw

# Alphabet sizes baked into the artifacts.  25 covers the 20 amino acids,
# ambiguity codes B/Z/X, the gap code, and a padding sentinel; 7 covers
# A/C/G/T(U) + N + gap + a distinct padding sentinel for nucleotide work
# (gap=5 and sentinel=6 must be different codes, or batcher padding is
# indistinguishable from real gap columns).
PROTEIN_ALPHA = 25
DNA_ALPHA = 7


def sw_align(a_codes, b_codes, subst, gap):
    """Batched SW wavefront against a broadcast center sequence (L1 kernel)."""
    return sw.sw_batch(a_codes, b_codes, subst, gap, interpret=True)


def kmer_sqdist(x):
    """Sampling-stage k-mer profile distances (L1 Gram kernel + algebra)."""
    return distance.kmer_sqdist(x, interpret=True)


def match_counts_dna(codes):
    """NJ-stage match counts over DNA/RNA alignments."""
    return distance.match_counts(codes, DNA_ALPHA, interpret=True)


def match_counts_protein(codes):
    """NJ-stage match counts over protein alignments (the one-hot width
    L*25 is zero-padded to the Gram tile width inside the kernel wrapper)."""
    return distance.match_counts(codes, PROTEIN_ALPHA, interpret=True)


def pad_cols_to(codes, width, fill):
    """Right-pad integer code rows to `width` with `fill` (a code both rows
    share, so padding adds a constant to every match count; the Rust caller
    subtracts it — see rust/src/tree/distance.rs)."""
    n, l = codes.shape
    assert width >= l
    return jnp.pad(codes, ((0, 0), (0, width - l)), constant_values=fill)

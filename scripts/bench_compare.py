#!/usr/bin/env python3
"""Compare fresh BENCH_*.json files against the committed baselines.

Required CI gate: after the bench smoke steps write BENCH_micro.json and
BENCH_serve.json at the repo root, this script diffs them against
BENCH_micro.baseline.json / BENCH_serve.baseline.json (also committed at
the repo root) and exits nonzero on any regression past the threshold.

What is compared — and deliberately what is not:

* Raw wall-clock seconds and absolute cells/sec are NEVER compared:
  they track the host, not the code, and a shared-runner gate on them
  would flake forever.
* micro: the bitparallel/scalar *ratio* per kernel is compared against
  the baseline ratio as a floor (measured >= baseline * (1 - threshold)).
  The ratio cancels the host's absolute speed; one-sided so a faster
  kernel never fails the gate.  Kernel names are normalized by stripping
  the trailing problem-size suffix (`global_400x400` -> `global`) so the
  QUICK and full modes hit the same baseline rows.
* serve: the scenario is deterministic by construction, so the cache
  counters (hits/misses/appends) are pinned exactly, the two correctness
  booleans must be true, and the measured speedup must meet the
  `min_speedup` floor (ratio of two same-host timings, so it is
  host-independent enough to gate on).  The per-append latency
  percentiles are gated the same way: absolute p50/p99 milliseconds are
  informational, but their ratio (`latency_tail_ratio` = p99/p50) must
  stay under the baseline's `max_tail_ratio` ceiling — a tail blowup is
  a code smell (one append falling off the incremental path) regardless
  of host speed.
* table5: the tiled/dense distmat `peak_bytes_ratio` must stay under the
  baseline's `max_peak_bytes_ratio` ceiling (tiled regressing to dense
  memory is the failure this catches), `backends_agree` must be true,
  the traced run must have executed tasks, and `critical_path_frac`
  must be a fraction in (0, `max_critical_path_frac`].
* fig6: for each scheduler mode (`sharded_`/`global_` prefixes), the
  forced steal / speculation / kill-drain episodes must appear as
  *minimum* counter floors (never exact pins — scheduling is
  nondeterministic), and `critical_path_frac` must stay under the
  ceiling: the speculation stage's deadline wait is wall-clock with no
  path on it, so a fraction near 1.0 means the profiler lost the gap.
* table2 is gated the same way as table5 (minus the peak ratio) when a
  fresh BENCH_table2.json is present; the file is optional so partial
  local runs still compare cleanly.

`--update` rewrites the baselines from the current BENCH files (keeping
every `min_*`/`max_*` floor and ceiling knob); commit the result.
"""

import argparse
import json
import re
import sys
from pathlib import Path

SIZE_SUFFIX = re.compile(r"_\d+(x\d+)?$")


def normalize_kernel(name):
    """global_400x400 / global_160x160 -> global; pdist_row_16384 -> pdist_row."""
    return SIZE_SUFFIX.sub("", name)


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except FileNotFoundError:
        print(f"bench_compare: missing {path}", file=sys.stderr)
        sys.exit(2)
    except json.JSONDecodeError as e:
        print(f"bench_compare: unparseable {path}: {e}", file=sys.stderr)
        sys.exit(2)


def micro_ratios(bench):
    """{normalized kernel: bitparallel cells_per_sec / scalar cells_per_sec}."""
    by_kernel = {}
    for row in bench.get("rows", []):
        by_kernel.setdefault(normalize_kernel(row["kernel"]), {})[row["backend"]] = row[
            "cells_per_sec"
        ]
    ratios = {}
    for kernel, backends in sorted(by_kernel.items()):
        if "scalar" in backends and "bitparallel" in backends and backends["scalar"] > 0:
            ratios[kernel] = backends["bitparallel"] / backends["scalar"]
    return ratios


def compare_micro(current, baseline, threshold):
    failures = []
    measured = micro_ratios(current)
    expected = baseline.get("kernels", {})
    for kernel, base in sorted(expected.items()):
        floor = base["min_ratio"] * (1.0 - threshold)
        got = measured.get(kernel)
        if got is None:
            failures.append(f"micro: kernel `{kernel}` missing from BENCH_micro.json")
        elif got < floor:
            failures.append(
                f"micro: {kernel} bitparallel/scalar ratio {got:.2f} below "
                f"baseline {base['min_ratio']:.2f} - {threshold:.0%} = {floor:.2f}"
            )
        else:
            print(f"  micro {kernel:<16} ratio {got:8.2f}  (floor {floor:.2f})  ok")
    for kernel in sorted(set(measured) - set(expected)):
        failures.append(
            f"micro: new kernel `{kernel}` has no baseline row "
            f"(run with --update and commit)"
        )
    return failures


def compare_serve(current, baseline):
    failures = []
    for key in ("hits", "misses", "appends"):
        want, got = baseline[key], current.get(key)
        if got != want:
            failures.append(f"serve: {key} = {got}, baseline pins {want}")
        else:
            print(f"  serve {key:<18} {got}  ok")
    for key in ("bit_identical", "peak_within_budget"):
        if current.get(key) is not True:
            failures.append(f"serve: {key} = {current.get(key)}, must be true")
        else:
            print(f"  serve {key:<18} true  ok")
    floor = baseline["min_speedup"]
    speedup = current.get("speedup", 0.0)
    if speedup < floor:
        failures.append(f"serve: append speedup {speedup:.1f}x below the {floor:.1f}x floor")
    else:
        print(f"  serve speedup            {speedup:.1f}x  (floor {floor:.1f}x)  ok")
    ceiling = baseline.get("max_tail_ratio", 50.0)
    tail = current.get("latency_tail_ratio")
    if tail is None:
        failures.append("serve: latency_tail_ratio missing from BENCH_serve.json")
    elif tail > ceiling:
        failures.append(
            f"serve: append p99/p50 latency ratio {tail:.1f} above the "
            f"{ceiling:.1f} ceiling"
        )
    else:
        print(f"  serve latency_tail_ratio {tail:.1f}  (ceiling {ceiling:.1f})  ok")
    return failures


def check_frac(failures, scenario, current, key, ceiling):
    """critical_path_frac-shaped value: must exist and sit in (0, ceiling]."""
    frac = current.get(key)
    if frac is None:
        failures.append(f"{scenario}: {key} missing")
    elif not 0.0 < frac <= ceiling:
        failures.append(
            f"{scenario}: {key} = {frac:.4f} outside (0, {ceiling:.2f}] "
            f"(ceiling from the committed baseline)"
        )
    else:
        print(f"  {scenario} {key:<28} {frac:.4f}  (ceiling {ceiling:.2f})  ok")


def check_counter_floor(failures, scenario, current, key, floor):
    got = current.get(key)
    if got is None or got < floor:
        failures.append(f"{scenario}: {key} = {got}, below the minimum of {floor}")
    else:
        print(f"  {scenario} {key:<28} {got}  (floor {floor})  ok")


def compare_table5(current, baseline):
    failures = []
    ceiling = baseline.get("max_peak_bytes_ratio", 1.0)
    ratio = current.get("peak_bytes_ratio")
    if ratio is None:
        failures.append("table5: peak_bytes_ratio missing from BENCH_table5.json")
    elif ratio > ceiling:
        failures.append(
            f"table5: tiled/dense peak_bytes_ratio {ratio:.3f} above the "
            f"{ceiling:.2f} ceiling (tiled backend regressed toward dense memory)"
        )
    else:
        print(f"  table5 {'peak_bytes_ratio':<28} {ratio:.4f}  (ceiling {ceiling:.2f})  ok")
    if current.get("backends_agree") is not True:
        failures.append(
            f"table5: backends_agree = {current.get('backends_agree')}, dense and "
            f"tiled must produce identical trees"
        )
    else:
        print(f"  table5 {'backends_agree':<28} true  ok")
    check_counter_floor(failures, "table5", current, "tasks_run", baseline.get("min_tasks_run", 1))
    check_frac(
        failures,
        "table5",
        current,
        "critical_path_frac",
        baseline.get("max_critical_path_frac", 1.0),
    )
    return failures


def compare_fig6(current, baseline):
    failures = []
    for prefix in ("sharded", "global"):
        for key, floor_key in (
            ("steals", "min_steals"),
            ("speculative_launches", "min_speculative_launches"),
            ("kill_drained", "min_kill_drained"),
        ):
            check_counter_floor(
                failures, "fig6", current, f"{prefix}_{key}", baseline.get(floor_key, 1)
            )
        check_frac(
            failures,
            "fig6",
            current,
            f"{prefix}_critical_path_frac",
            baseline.get("max_critical_path_frac", 0.95),
        )
    return failures


def compare_table2(current, baseline):
    failures = []
    if current.get("sp_match") is not True:
        failures.append(
            f"table2: sp_match = {current.get('sp_match')}, HAlign v1 and HAlign-II "
            f"must report the same avg SP"
        )
    else:
        print(f"  table2 {'sp_match':<28} true  ok")
    check_counter_floor(failures, "table2", current, "tasks_run", baseline.get("min_tasks_run", 1))
    check_frac(
        failures,
        "table2",
        current,
        "critical_path_frac",
        baseline.get("max_critical_path_frac", 1.0),
    )
    return failures


def profiled_baseline(scenario, current, old, knobs):
    """Baseline for a profiled scenario: every fresh key is echoed (W9
    requires written keys to appear in the baseline) plus the gate knobs,
    preserved from the old baseline when present."""
    base = {"bench": scenario}
    base.update(current)
    for knob, default in knobs.items():
        base[knob] = old.get(knob, default)
    return base


PROFILE_KNOBS = {
    "table5": {"max_peak_bytes_ratio": 1.0, "min_tasks_run": 1, "max_critical_path_frac": 1.0},
    "fig6": {
        "min_steals": 1,
        "min_speculative_launches": 1,
        "min_kill_drained": 1,
        "max_critical_path_frac": 0.95,
    },
    "table2": {"min_tasks_run": 1, "max_critical_path_frac": 1.0},
}


def update_baselines(root, micro, serve, old_serve_baseline):
    micro_base = {
        "bench": "micro_kernel_ab",
        "note": "floors for the bitparallel/scalar cells_per_sec ratio; "
        "kernel names are size-normalized",
        "kernels": {
            kernel: {"min_ratio": round(ratio, 2)}
            for kernel, ratio in sorted(micro_ratios(micro).items())
        },
    }
    serve_base = {
        "bench": "serve_append",
        "hits": serve["hits"],
        "misses": serve["misses"],
        "appends": serve["appends"],
        "bit_identical": True,
        "peak_within_budget": True,
        "min_speedup": old_serve_baseline.get("min_speedup", 5.0),
        "max_tail_ratio": old_serve_baseline.get("max_tail_ratio", 50.0),
    }
    updates = [
        ("BENCH_micro.baseline.json", micro_base),
        ("BENCH_serve.baseline.json", serve_base),
    ]
    for scenario, knobs in PROFILE_KNOBS.items():
        fresh = root / f"BENCH_{scenario}.json"
        if not fresh.exists():
            print(f"skipping BENCH_{scenario}.baseline.json (no fresh {fresh.name})")
            continue
        old_path = root / f"BENCH_{scenario}.baseline.json"
        old = json.loads(old_path.read_text()) if old_path.exists() else {}
        updates.append(
            (
                f"BENCH_{scenario}.baseline.json",
                profiled_baseline(scenario, load(fresh), old, knobs),
            )
        )
    for name, data in updates:
        path = root / name
        path.write_text(json.dumps(data, indent=2) + "\n")
        print(f"rewrote {path}")


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--root",
        type=Path,
        default=Path(__file__).resolve().parent.parent,
        help="repo root holding BENCH_*.json and the baselines (default: ../ of this script)",
    )
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.10,
        help="allowed fractional slack under a baseline ratio floor (default 0.10)",
    )
    ap.add_argument(
        "--update",
        action="store_true",
        help="rewrite the baselines from the current BENCH files instead of comparing",
    )
    args = ap.parse_args()

    micro = load(args.root / "BENCH_micro.json")
    serve = load(args.root / "BENCH_serve.json")
    serve_baseline = load(args.root / "BENCH_serve.baseline.json")
    if args.update:
        update_baselines(args.root, micro, serve, serve_baseline)
        return
    micro_baseline = load(args.root / "BENCH_micro.baseline.json")

    failures = compare_micro(micro, micro_baseline, args.threshold)
    failures += compare_serve(serve, serve_baseline)
    # Profiled scenarios: table5 and fig6 are required (CI produces both),
    # table2 is compared only when a fresh file is present.
    comparators = {"table5": compare_table5, "fig6": compare_fig6, "table2": compare_table2}
    for scenario, compare in comparators.items():
        fresh_path = args.root / f"BENCH_{scenario}.json"
        if scenario == "table2" and not fresh_path.exists():
            print(f"  table2 skipped (no fresh {fresh_path.name})")
            continue
        fresh = load(fresh_path)
        baseline = load(args.root / f"BENCH_{scenario}.baseline.json")
        failures += compare(fresh, baseline)
    if failures:
        print(f"\nbench_compare: {len(failures)} regression(s):", file=sys.stderr)
        for f in failures:
            print(f"  FAIL {f}", file=sys.stderr)
        sys.exit(1)
    print("bench_compare: all benchmarks within thresholds")


if __name__ == "__main__":
    main()

#!/usr/bin/env python3
"""Compare fresh BENCH_*.json files against the committed baselines.

Required CI gate: after the bench smoke steps write BENCH_micro.json and
BENCH_serve.json at the repo root, this script diffs them against
BENCH_micro.baseline.json / BENCH_serve.baseline.json (also committed at
the repo root) and exits nonzero on any regression past the threshold.

What is compared — and deliberately what is not:

* Raw wall-clock seconds and absolute cells/sec are NEVER compared:
  they track the host, not the code, and a shared-runner gate on them
  would flake forever.
* micro: the bitparallel/scalar *ratio* per kernel is compared against
  the baseline ratio as a floor (measured >= baseline * (1 - threshold)).
  The ratio cancels the host's absolute speed; one-sided so a faster
  kernel never fails the gate.  Kernel names are normalized by stripping
  the trailing problem-size suffix (`global_400x400` -> `global`) so the
  QUICK and full modes hit the same baseline rows.
* serve: the scenario is deterministic by construction, so the cache
  counters (hits/misses/appends) are pinned exactly, the two correctness
  booleans must be true, and the measured speedup must meet the
  `min_speedup` floor (ratio of two same-host timings, so it is
  host-independent enough to gate on).  The per-append latency
  percentiles are gated the same way: absolute p50/p99 milliseconds are
  informational, but their ratio (`latency_tail_ratio` = p99/p50) must
  stay under the baseline's `max_tail_ratio` ceiling — a tail blowup is
  a code smell (one append falling off the incremental path) regardless
  of host speed.

`--update` rewrites the baselines from the current BENCH files (keeping
serve's `min_speedup` floor and `max_tail_ratio` ceiling); commit the
result.
"""

import argparse
import json
import re
import sys
from pathlib import Path

SIZE_SUFFIX = re.compile(r"_\d+(x\d+)?$")


def normalize_kernel(name):
    """global_400x400 / global_160x160 -> global; pdist_row_16384 -> pdist_row."""
    return SIZE_SUFFIX.sub("", name)


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except FileNotFoundError:
        print(f"bench_compare: missing {path}", file=sys.stderr)
        sys.exit(2)
    except json.JSONDecodeError as e:
        print(f"bench_compare: unparseable {path}: {e}", file=sys.stderr)
        sys.exit(2)


def micro_ratios(bench):
    """{normalized kernel: bitparallel cells_per_sec / scalar cells_per_sec}."""
    by_kernel = {}
    for row in bench.get("rows", []):
        by_kernel.setdefault(normalize_kernel(row["kernel"]), {})[row["backend"]] = row[
            "cells_per_sec"
        ]
    ratios = {}
    for kernel, backends in sorted(by_kernel.items()):
        if "scalar" in backends and "bitparallel" in backends and backends["scalar"] > 0:
            ratios[kernel] = backends["bitparallel"] / backends["scalar"]
    return ratios


def compare_micro(current, baseline, threshold):
    failures = []
    measured = micro_ratios(current)
    expected = baseline.get("kernels", {})
    for kernel, base in sorted(expected.items()):
        floor = base["min_ratio"] * (1.0 - threshold)
        got = measured.get(kernel)
        if got is None:
            failures.append(f"micro: kernel `{kernel}` missing from BENCH_micro.json")
        elif got < floor:
            failures.append(
                f"micro: {kernel} bitparallel/scalar ratio {got:.2f} below "
                f"baseline {base['min_ratio']:.2f} - {threshold:.0%} = {floor:.2f}"
            )
        else:
            print(f"  micro {kernel:<16} ratio {got:8.2f}  (floor {floor:.2f})  ok")
    for kernel in sorted(set(measured) - set(expected)):
        failures.append(
            f"micro: new kernel `{kernel}` has no baseline row "
            f"(run with --update and commit)"
        )
    return failures


def compare_serve(current, baseline):
    failures = []
    for key in ("hits", "misses", "appends"):
        want, got = baseline[key], current.get(key)
        if got != want:
            failures.append(f"serve: {key} = {got}, baseline pins {want}")
        else:
            print(f"  serve {key:<18} {got}  ok")
    for key in ("bit_identical", "peak_within_budget"):
        if current.get(key) is not True:
            failures.append(f"serve: {key} = {current.get(key)}, must be true")
        else:
            print(f"  serve {key:<18} true  ok")
    floor = baseline["min_speedup"]
    speedup = current.get("speedup", 0.0)
    if speedup < floor:
        failures.append(f"serve: append speedup {speedup:.1f}x below the {floor:.1f}x floor")
    else:
        print(f"  serve speedup            {speedup:.1f}x  (floor {floor:.1f}x)  ok")
    ceiling = baseline.get("max_tail_ratio", 50.0)
    tail = current.get("latency_tail_ratio")
    if tail is None:
        failures.append("serve: latency_tail_ratio missing from BENCH_serve.json")
    elif tail > ceiling:
        failures.append(
            f"serve: append p99/p50 latency ratio {tail:.1f} above the "
            f"{ceiling:.1f} ceiling"
        )
    else:
        print(f"  serve latency_tail_ratio {tail:.1f}  (ceiling {ceiling:.1f})  ok")
    return failures


def update_baselines(root, micro, serve, old_serve_baseline):
    micro_base = {
        "bench": "micro_kernel_ab",
        "note": "floors for the bitparallel/scalar cells_per_sec ratio; "
        "kernel names are size-normalized",
        "kernels": {
            kernel: {"min_ratio": round(ratio, 2)}
            for kernel, ratio in sorted(micro_ratios(micro).items())
        },
    }
    serve_base = {
        "bench": "serve_append",
        "hits": serve["hits"],
        "misses": serve["misses"],
        "appends": serve["appends"],
        "bit_identical": True,
        "peak_within_budget": True,
        "min_speedup": old_serve_baseline.get("min_speedup", 5.0),
        "max_tail_ratio": old_serve_baseline.get("max_tail_ratio", 50.0),
    }
    for name, data in [
        ("BENCH_micro.baseline.json", micro_base),
        ("BENCH_serve.baseline.json", serve_base),
    ]:
        path = root / name
        path.write_text(json.dumps(data, indent=2) + "\n")
        print(f"rewrote {path}")


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--root",
        type=Path,
        default=Path(__file__).resolve().parent.parent,
        help="repo root holding BENCH_*.json and the baselines (default: ../ of this script)",
    )
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.10,
        help="allowed fractional slack under a baseline ratio floor (default 0.10)",
    )
    ap.add_argument(
        "--update",
        action="store_true",
        help="rewrite the baselines from the current BENCH files instead of comparing",
    )
    args = ap.parse_args()

    micro = load(args.root / "BENCH_micro.json")
    serve = load(args.root / "BENCH_serve.json")
    serve_baseline = load(args.root / "BENCH_serve.baseline.json")
    if args.update:
        update_baselines(args.root, micro, serve, serve_baseline)
        return
    micro_baseline = load(args.root / "BENCH_micro.baseline.json")

    failures = compare_micro(micro, micro_baseline, args.threshold)
    failures += compare_serve(serve, serve_baseline)
    if failures:
        print(f"\nbench_compare: {len(failures)} regression(s):", file=sys.stderr)
        for f in failures:
            print(f"  FAIL {f}", file=sys.stderr)
        sys.exit(1)
    print("bench_compare: all benchmarks within thresholds")


if __name__ == "__main__":
    main()
